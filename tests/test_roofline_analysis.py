"""hlo_cost analyzer tests: trip counts, dot flops, collective wire bytes."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import hlo_cost
from repro.utils.compat import compiled_cost_analysis


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, _):
            return (c @ w).astype(jnp.bfloat16).astype(jnp.float32), None

        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = _compile(f, s, s)
    summ = hlo_cost.analyze(c.as_text(), 1)
    assert summ.flops == pytest.approx(2 * 64**3 * 10)
    assert summ.unknown_trip_loops == 0
    # XLA's own counter misses the ×10 — the reason this module exists
    xla = compiled_cost_analysis(c).get("flops", 0.0)
    assert xla < summ.flops / 5


def test_nested_scan_multiplies():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None

            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None

        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    s = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    summ = hlo_cost.analyze(_compile(f, s, s).as_text(), 1)
    assert summ.flops == pytest.approx(2 * 32**3 * 12)


def test_plain_matmul_flops_and_bytes():
    s = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    summ = hlo_cost.analyze(_compile(lambda a, b: a @ b, s, w).as_text(), 1)
    assert summ.flops == pytest.approx(2 * 128 * 256 * 512)
    min_bytes = (128 * 256 + 256 * 512 + 128 * 512) * 4
    assert summ.hbm_bytes >= min_bytes
    assert summ.hbm_bytes < 3 * min_bytes


def test_shape_parsing_helpers():
    shapes = hlo_cost._parse_shapes("(f32[128,64]{1,0}, bf16[2]{0}, pred[])")
    assert hlo_cost._shape_bytes(shapes) == 128 * 64 * 4 + 2 * 2 + 1


def test_dryrun_line_parser_group_formats():
    from repro.launch.dryrun import parse_collectives

    hlo = """
ENTRY %e () -> f32[] {
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = f32[2048]{0} all-gather(%y), replica_groups=[2,8]<=[16], dimensions={0}
}
"""
    rec = parse_collectives(hlo, 16)
    assert rec["counts"]["all-reduce"] == 1
    assert rec["bytes_by_kind"]["all-reduce"] == pytest.approx(
        1024 * 4 * 2 * 3 / 4
    )
    assert rec["bytes_by_kind"]["all-gather"] == pytest.approx(2048 * 4 * 7 / 8)


def test_roofline_summary_roundtrip(tmp_path):
    import json

    from repro.analysis import roofline

    rec = {
        "arch": "a", "cell": "train_4k", "multi_pod": False, "chips": 256,
        "status": "ok",
        "terms_s": {"compute_s": 0.5, "memory_s": 0.25, "collective_s": 0.1},
        "bottleneck": "compute_s",
        "model_flops_global": 0.5 * 256 * roofline.PEAK_FLOPS,
        "useful_flops_ratio": 1.0,
        "memory_analysis": {"temp_size_in_bytes": 2**30},
    }
    (tmp_path / "a.train_4k.single.json").write_text(json.dumps(rec))
    rows = roofline.summarize(str(tmp_path))
    assert len(rows) == 1
    r = rows[0]
    assert r["fraction"] == pytest.approx(1.0)
    assert r["mfu"] == pytest.approx(1.0)
    assert r["bottleneck"] == "compute"


def test_all_gather_is_counted():
    """Regression: 'all-gather'.rstrip('-start') == 'all-gathe' silently
    dropped every all-gather from the collective term."""
    hlo = """
ENTRY %e (p: f32[64,128]) -> f32[64,2048] {
  %p = f32[64,128]{1,0} parameter(0)
  ROOT %ag = f32[64,2048]{1,0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={1}
}
"""
    s = hlo_cost.analyze(hlo, 256)
    assert s.collective_counts.get("all-gather") == 1
    assert s.wire_bytes == pytest.approx(64 * 2048 * 4 * 15 / 16)


def test_reduce_scatter_is_counted():
    hlo = """
ENTRY %e (p: f32[64,2048]) -> f32[64,128] {
  %p = f32[64,2048]{1,0} parameter(0)
  ROOT %rs = f32[64,128]{1,0} reduce-scatter(%p), replica_groups=[16,16]<=[256], dimensions={1}, to_apply=%sum
}
"""
    s = hlo_cost.analyze(hlo, 256)
    assert s.collective_counts.get("reduce-scatter") == 1
    assert s.wire_bytes == pytest.approx(64 * 128 * 4 * 15)
