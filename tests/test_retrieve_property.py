"""Hypothesis property tests for retrieve / inner_join.

The checkers are plain functions over numpy inputs (also exercised by the
deterministic suite); hypothesis drives them with arbitrary multisets,
adversarial single-bucket tables, and duplicate-heavy distributions.
Skipped cleanly when hypothesis is absent (see requirements-dev.txt).
"""
from collections import defaultdict

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import hashgraph

keys_strategy = st.lists(
    st.integers(min_value=0, max_value=2**32 - 2), min_size=1, max_size=200
)


def _oracle(keys, values):
    d = defaultdict(list)
    for k, v in zip(keys, values):
        d[int(k)].append(int(v))
    return d


def check_retrieve_matches_oracle(build, queries, table_size):
    """Core property: retrieve returns exactly the stored multiset per key."""
    keys = np.array(build, np.uint32)
    values = np.arange(len(keys), dtype=np.int32)
    hg = hashgraph.build(
        jnp.asarray(keys), table_size=table_size, values=jnp.asarray(values)
    )
    oracle = _oracle(keys, values)
    q = np.array(queries, np.uint32)
    total = sum(len(oracle[int(k)]) for k in q)
    offsets, vals, dropped = hashgraph.retrieve(
        hg, jnp.asarray(q), capacity=total + 8
    )
    assert int(dropped) == 0
    offsets, vals = np.asarray(offsets), np.asarray(vals)
    for i, k in enumerate(q):
        assert sorted(vals[offsets[i] : offsets[i + 1]].tolist()) == sorted(
            oracle[int(k)]
        )
    # CSR run lengths must agree with the counting query
    counts = np.asarray(hashgraph.query_count_sorted(hg, jnp.asarray(q)))
    np.testing.assert_array_equal(np.diff(offsets), counts)


def check_join_matches_oracle(build, queries, table_size):
    keys = np.array(build, np.uint32)
    values = np.arange(len(keys), dtype=np.int32)
    hg = hashgraph.build(
        jnp.asarray(keys), table_size=table_size, values=jnp.asarray(values)
    )
    oracle = _oracle(keys, values)
    q = np.array(queries, np.uint32)
    total = sum(len(oracle[int(k)]) for k in q)
    qidx, vals, num_results, dropped = hashgraph.inner_join(
        hg, jnp.asarray(q), capacity=total + 8
    )
    assert int(dropped) == 0 and int(num_results) == total
    got = sorted(
        (int(a), int(b))
        for a, b in zip(np.asarray(qidx)[:total], np.asarray(vals)[:total])
    )
    want = sorted((i, v) for i, k in enumerate(q) for v in oracle[int(k)])
    assert got == want


def check_overflow_exact(build, queries, capacity):
    keys = np.array(build, np.uint32)
    values = np.arange(len(keys), dtype=np.int32)
    hg = hashgraph.build(
        jnp.asarray(keys), table_size=max(1, len(keys) // 2), values=jnp.asarray(values)
    )
    q = np.array(queries, np.uint32)
    total = int(
        np.asarray(hashgraph.query_count_sorted(hg, jnp.asarray(q))).sum()
    )
    offsets, vals, dropped = hashgraph.retrieve(hg, jnp.asarray(q), capacity=capacity)
    assert int(dropped) == max(0, total - capacity)
    assert int(np.asarray(offsets).max()) <= capacity
    # emitted slots are a prefix of the untruncated result stream
    _, vals_full, _ = hashgraph.retrieve(hg, jnp.asarray(q), capacity=total + 1)
    m = min(capacity, total)
    np.testing.assert_array_equal(np.asarray(vals)[:m], np.asarray(vals_full)[:m])


@settings(max_examples=40, deadline=None)
@given(build=keys_strategy, queries=keys_strategy, c_inv=st.integers(1, 4))
def test_retrieve_any_multiset(build, queries, c_inv):
    check_retrieve_matches_oracle(build, queries, max(1, len(build) // c_inv))


@settings(max_examples=25, deadline=None)
@given(build=keys_strategy, queries=keys_strategy)
def test_retrieve_adversarial_single_bucket(build, queries):
    """table_size=1: every key collides into one bucket chain."""
    check_retrieve_matches_oracle(build, queries, 1)


@settings(max_examples=25, deadline=None)
@given(
    base=st.lists(st.integers(0, 2**20), min_size=1, max_size=24),
    mult=st.integers(1, 64),
    c_inv=st.integers(1, 4),
)
def test_retrieve_duplicate_heavy(base, mult, c_inv):
    """Uniform heavy duplication: each key repeated ``mult`` times."""
    build = [k for k in base for _ in range(mult)]
    check_retrieve_matches_oracle(
        build, base, max(1, len(build) // c_inv)
    )


@settings(max_examples=30, deadline=None)
@given(build=keys_strategy, queries=keys_strategy, c_inv=st.integers(1, 4))
def test_join_any_multiset(build, queries, c_inv):
    check_join_matches_oracle(build, queries, max(1, len(build) // c_inv))


@settings(max_examples=25, deadline=None)
@given(build=keys_strategy, queries=keys_strategy, capacity=st.integers(1, 64))
def test_overflow_reported_exactly(build, queries, capacity):
    check_overflow_exact(build, queries, capacity)


# ---------------------------------------------------------------------------
# distributed: fixed shapes (one jit cache entry), hypothesis drives the data
# ---------------------------------------------------------------------------

_N_KEYS, _N_QUERIES = 1024, 512


def check_distributed_retrieve(seed, max_mult, mesh):
    from repro.core.table import DistributedHashTable, retrieval_to_lists

    rng = np.random.default_rng(seed)
    base = rng.choice(np.arange(1 << 16, dtype=np.uint32), size=128, replace=False)
    mult = rng.integers(1, max_mult + 1, size=128)
    keys = np.repeat(base, mult)[: _N_KEYS]
    keys = np.concatenate(
        [keys, rng.choice(base, size=_N_KEYS - len(keys))]
    ) if len(keys) < _N_KEYS else keys[:_N_KEYS]
    rng.shuffle(keys)
    values = np.arange(_N_KEYS, dtype=np.int32)
    table = DistributedHashTable(
        mesh, ("d",), hash_range=1 << 10, capacity_slack=4.0
    )
    state = table.build(jnp.asarray(keys), values=jnp.asarray(values))
    assert int(state.num_dropped) == 0
    oracle = _oracle(keys, values)
    queries = np.concatenate(
        [
            rng.choice(base, size=_N_QUERIES // 2),
            rng.integers(1 << 16, 1 << 17, size=_N_QUERIES // 2).astype(np.uint32),
        ]
    )
    rng.shuffle(queries)
    res = table.retrieve(
        state, jnp.asarray(queries), out_capacity=2 * _N_KEYS, seg_capacity=2 * _N_KEYS
    )
    assert int(res.num_dropped) == 0
    per_q = retrieval_to_lists(res)
    for i, k in enumerate(queries):
        assert sorted(np.asarray(per_q[i]).tolist()) == sorted(oracle[int(k)])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), max_mult=st.integers(1, 64))
def test_distributed_retrieve_property(seed, max_mult, mesh8):
    check_distributed_retrieve(seed, max_mult, mesh8)
