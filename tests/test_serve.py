"""Serving tests: prefill/decode ≡ teacher-forced forward; batcher drains."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.distributed.parallel import single_device_parallel
from repro.models.api import build_model
from repro.models import transformer as tfm
from repro.serve import ContinuousBatcher, Request, make_prefill_step, make_serve_step

ARCHS_DECODE_EXACT = ["qwen3_4b", "granite_20b", "mixtral_8x22b", "xlstm_1_3b",
                      "recurrentgemma_9b"]


@pytest.mark.parametrize("arch", ARCHS_DECODE_EXACT)
def test_prefill_plus_decode_matches_forward(arch):
    """logits from (prefill → step-by-step decode) == full forward pass.

    f32 smoke config so the equality is tight; this is the strongest
    internal-consistency check on the KV-cache/ring-buffer/state paths.
    """
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    bundle = build_model(cfg, single_device_parallel())
    params = bundle.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    total = 12
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, total + 1), np.int32))

    # teacher-forced forward logits at each position
    full_logits, _ = tfm.forward_train(params, toks, cfg, None)

    # prefill on the first 4, then decode positions 4..total-1
    plen = 4
    logits_p, caches = bundle.prefill(
        params, {"tokens": toks[:, :plen]}, cache_len=total
    )
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, plen - 1]),
        rtol=2e-4, atol=2e-4,
    )
    for t in range(plen, total):
        tok = toks[:, t: t + 1]
        pos = jnp.full((1,), t, jnp.int32)
        logits_d, caches = bundle.decode_step(params, caches, tok, pos)
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, t]),
            rtol=3e-4, atol=3e-4,
            err_msg=f"{arch} decode mismatch at position {t}",
        )


def test_continuous_batcher_drains_all_requests():
    cfg = dataclasses.replace(get_smoke_config("qwen3_4b"), dtype="float32")
    bundle = build_model(cfg, single_device_parallel())
    params = bundle.init(jax.random.key(1))
    slots, cache_len = 3, 64
    caches = bundle.init_cache(slots, cache_len)
    batcher = ContinuousBatcher(
        params,
        caches,
        make_prefill_step(bundle, cache_len=cache_len),
        make_serve_step(bundle, donate=False),
        num_slots=slots,
    )
    rng = np.random.default_rng(2)
    n_req = 7
    for uid in range(n_req):
        batcher.submit(
            Request(
                uid=uid,
                prompt=rng.integers(1, cfg.vocab_size, size=8, dtype=np.int32),
                max_new_tokens=5,
            )
        )
    done = batcher.run_until_drained(max_steps=200)
    assert len(done) == n_req
    assert all(len(r.out_tokens) == 5 for r in done)
    assert sorted(r.uid for r in done) == list(range(n_req))


def test_batcher_greedy_matches_manual_decode():
    """One request through the batcher == manual greedy decode loop."""
    cfg = dataclasses.replace(get_smoke_config("qwen3_4b"), dtype="float32")
    bundle = build_model(cfg, single_device_parallel())
    params = bundle.init(jax.random.key(3))
    prompt = np.arange(1, 9, dtype=np.int32)
    cache_len = 64

    # manual reference
    logits, caches = bundle.prefill(
        params, {"tokens": jnp.asarray(prompt[None])}, cache_len=cache_len
    )
    out_ref = [int(jnp.argmax(logits[0]))]
    for i in range(3):
        tok = jnp.asarray([[out_ref[-1]]], jnp.int32)
        pos = jnp.full((1,), len(prompt) + i, jnp.int32)
        logits, caches = bundle.decode_step(params, caches, tok, pos)
        out_ref.append(int(jnp.argmax(logits[0])))

    batcher = ContinuousBatcher(
        params,
        bundle.init_cache(2, cache_len),
        make_prefill_step(bundle, cache_len=cache_len),
        make_serve_step(bundle, donate=False),
        num_slots=2,
    )
    batcher.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
    done = batcher.run_until_drained()
    assert done[0].out_tokens == out_ref
