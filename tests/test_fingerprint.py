"""Fingerprint probe lane — engineered collisions, parity, fold, collectives.

The fingerprint-compressed probe path bisects a 1-lane uint32 fingerprint
array first and touches full key lanes only inside the matched fingerprint
run.  Its correctness story therefore rests on the *collision* case: two
distinct keys with equal fingerprints share a run, and the verification
bisection must separate them exactly — multiset counts, retrieved value
multisets, tombstone semantics all unchanged.

This suite manufactures real collisions instead of hoping for them: it
fingerprints a large random u64 candidate pool on device (the same
``fingerprint32`` the table uses) and mines birthday pairs with numpy.
One structural fact shapes the adversarial grid: every step of the murmur3
mix is invertible, so a message where only ONE 32-bit lane varies maps
that lane *bijectively* to the hash.  Consequences the tests encode:

* u32x1 — distinct 1-lane keys can never share a fingerprint; the
  fingerprint run degenerates to the equal-key multiplicity run, and the
  lane is pure overhead (which is why it defaults off for 1-lane keys).
* u64x2 — true fingerprint collisions exist only between keys differing
  in BOTH lanes (mined pairs); keys sharing the low or the high lane
  necessarily differ in fingerprint, so they instead stress the packed
  big-int compare inside a (fingerprint, key)-sorted bucket, where a
  single lane is all that separates them.

Grid: engineered collisions at multiplicity up to 1024, u32x1/u64x2 ×
mesh1/mesh8, fingerprint path vs forced-full-key path (byte-identical),
delete-then-reinsert across a ``fold_oldest`` boundary (epoch remap with
fingerprints present), and the fused-routing collective budget (exactly 2
all-to-alls per op) with the fingerprint lane on.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing, plans
from repro.core.maintenance import fold_oldest
from repro.core.schema import TableSchema, pack_u64
from repro.core.table import DistributedHashTable, retrieval_to_lists
from test_fused_routing import count_primitive
from test_table_state import _value_rows, _values_for

SCHEMAS = [
    pytest.param(TableSchema("uint32", 1), id="u32x1"),
    pytest.param(TableSchema("uint64", 2), id="u64x2"),
]


@functools.lru_cache(maxsize=None)
def _adversarial_pairs(key_dtype: str):
    """Three key pairs stressing the (fingerprint, key) probe layout.

    uint64: pair 0 is a *mined* true fingerprint collision — a 2^19
    random pool yields ~46 birthday pairs at 32-bit fingerprints,
    deterministic given the seed; both lanes differ (they must — the
    murmur mix is a bijection of any single varying lane).  Pair 1
    shares the low key lane, pair 2 the high lane: their fingerprints
    necessarily differ, so they exercise the packed compare that
    separates near-identical keys landing in one sorted bucket.

    uint32: the 1-lane fingerprint is a bijection of the key — distinct
    keys NEVER collide — so the pairs are plain distinct keys and the
    tests degenerate to multiplicity-run + parity coverage (the reason
    the lane defaults off for 1-lane schemas).
    """
    if key_dtype == "uint32":
        return ((0x0000BEEF, 0x0001BEEF), (3, 0x10003), (5, 0x20005))
    n = 1 << 19
    rng = np.random.default_rng(0xF1D0)
    raw = np.unique(rng.integers(0, 1 << 63, size=n, dtype=np.uint64))
    fp = np.asarray(hashing.fingerprint32(pack_u64(raw)))
    order = np.argsort(fp, kind="stable")
    fps = fp[order]
    dup = np.flatnonzero(fps[1:] == fps[:-1])
    assert len(dup) > 0, "collision mining failed — widen the pool"
    k1, k2 = sorted((int(raw[order[dup[0]]]), int(raw[order[dup[0] + 1]])))
    assert k1 != k2 and fp[order[dup[0]]] == fp[order[dup[0] + 1]]
    low_pair = (0x7_0000_1111, 0xBAD_0000_1111)  # equal low lane
    high_pair = (0x7777_0000_0000_0003, 0x7777_0000_0000_0009)  # equal high lane
    return ((k1, k2), low_pair, high_pair)


def _table(mesh, schema, fingerprint, **kw):
    # generous dispatch slack: a multiplicity-700 key routes every copy to
    # ONE owner shard (hot-key skew — see the ROADMAP replication item),
    # so per-shard capacity must cover the whole run, not the average
    return DistributedHashTable(
        mesh,
        ("d",),
        hash_range=1 << 12,
        schema=schema,
        fingerprint=fingerprint,
        capacity_slack=kw.pop("capacity_slack", 6.0),
        **kw,
    )


def _pack(schema, host_keys):
    return schema.pack_keys(np.asarray(host_keys, dtype=schema.key_dtype))


@pytest.mark.parametrize("schema", SCHEMAS)
@pytest.mark.parametrize("meshname", ["mesh1", "mesh8"])
def test_engineered_collisions_exact(schema, meshname, request):
    """Adversarial keys at multiplicity ≤1024: exact counts, exact value
    multisets (no cross-leak between fp-colliding keys), byte-identical
    to the forced full-key path."""
    mesh = request.getfixturevalue(meshname)
    (k1, k2), (la, lb), (ha, hb) = _adversarial_pairs(schema.key_dtype)
    rng = np.random.default_rng(3)

    # workload: k1 × 700 + k2 × 300 — for u64 one shared-fingerprint run
    # of 1000 (≤ 1024), whose verification pass must split 700/300 exactly
    # — plus the lane-sharing pairs and background noise.
    special = [(k1, 700), (k2, 300), (la, 17), (lb, 9), (ha, 5), (hb, 3)]
    lo, hi = (1 << 33, 1 << 34) if schema.key_dtype == "uint64" else (1 << 20, 1 << 31)
    # total padded to 2048 so the global array shards evenly on mesh8
    noise = rng.integers(lo, hi, size=2048 - sum(m for _, m in special)).astype(
        np.uint64
    )
    host = np.concatenate(
        [
            np.repeat(
                np.asarray([k for k, _ in special], np.uint64),
                [m for _, m in special],
            ),
            noise,
        ]
    ).astype(schema.key_dtype)
    values = _values_for(schema, 0, len(host))
    # shuffle so hot-key copies spread across *sender* shards — contiguous
    # runs overflow one sender's per-pair dispatch slot no matter the
    # owner-side slack (hot-key replication is a ROADMAP item)
    perm = np.random.default_rng(7).permutation(len(host))
    host, values = host[perm], values[perm]
    # oracle from the workload itself — robust to accidental aliasing
    expect = {}
    for k, v in zip(host.tolist(), _value_rows(values)):
        expect.setdefault(k, []).append(v)

    queries = np.asarray(
        [k1, k2, la, lb, ha, hb, k1 + 5, noise[0]], dtype=schema.key_dtype
    )
    want_counts = np.asarray(
        [len(expect.get(int(q), [])) for q in queries], np.int32
    )
    assert want_counts[0] == 700 and want_counts[1] == 300  # no aliasing

    res = {}
    for fp_on in (True, False):
        table = _table(mesh, schema, fp_on)
        state = table.init(_pack(schema, host), values=jnp.asarray(values))
        assert int(state.num_dropped) == 0, "dispatch capacity sizing bug"
        assert (table.use_fingerprint, state.base.local.fingerprints is not None) == (
            fp_on,
            fp_on,
        )
        counts = np.asarray(table.query(state, _pack(schema, queries)))
        np.testing.assert_array_equal(counts, want_counts)
        r = table.retrieve(state, _pack(schema, queries))
        assert int(r.num_dropped) == 0
        res[fp_on] = r
        per_q = retrieval_to_lists(r)
        for i, q in enumerate(queries.tolist()):
            got = sorted(_value_rows(np.asarray(per_q[i])))
            assert got == sorted(expect.get(int(q), [])), f"query {i}"

    for field in ("offsets", "counts", "values", "num_dropped"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res[True], field)),
            np.asarray(getattr(res[False], field)),
            err_msg=f"fingerprint path diverged on {field}",
        )


@pytest.mark.parametrize("schema", SCHEMAS)
@pytest.mark.parametrize("meshname", ["mesh1", "mesh8"])
def test_collision_delete_reinsert_across_fold(schema, meshname, request):
    """Tombstone one colliding key, fold the epoch away, reinsert: the epoch
    remap must keep the surviving collision partner intact throughout."""
    mesh = request.getfixturevalue(meshname)
    (k1, k2), _, _ = _adversarial_pairs(schema.key_dtype)
    table = _table(mesh, schema, True, max_deltas=6)

    # all batch sizes are multiples of 8 so arrays shard evenly on mesh8
    base = np.repeat(np.asarray([k1, k2], np.uint64), [8, 8]).astype(schema.key_dtype)
    v0 = _values_for(schema, 0, 16)
    state = table.init(_pack(schema, base), values=jnp.asarray(v0))
    # delta 1: more of both colliding keys; delta 2: unrelated filler
    v1 = _values_for(schema, 100, 8)
    state = state.insert(
        _pack(schema, np.repeat(np.asarray([k1, k2], np.uint64), [4, 4]).astype(
            schema.key_dtype
        )),
        jnp.asarray(v1),
    )
    state = state.insert(
        _pack(schema, np.full(8, k1 + 7, schema.key_dtype)),
        jnp.asarray(_values_for(schema, 200, 8)),
    )
    # tombstone k1 everywhere (epoch 2), then fold the two oldest layers —
    # the tombstone epoch indices must remap with fingerprints present
    misses = np.asarray([k1 + i for i in range(100, 107)], schema.key_dtype)
    state = state.delete(
        _pack(schema, np.concatenate([[np.uint64(k1)], misses.astype(np.uint64)])
              .astype(schema.key_dtype))
    )
    folded = fold_oldest(state, 2)
    assert folded.base.local.fingerprints is not None

    q = _pack(
        schema,
        np.concatenate(
            [np.asarray([k1, k2, k1 + 7], np.uint64), misses[:5].astype(np.uint64)]
        ).astype(schema.key_dtype),
    )
    want0 = [0, 12, 8, 0, 0, 0, 0, 0]
    np.testing.assert_array_equal(np.asarray(table.query(folded, q)), want0)

    # reinsert k1 after the fold: fresh rows live, old rows stay dead
    v9 = _values_for(schema, 900, 8)
    refreshed = folded.insert(
        _pack(schema, np.full(8, k1, schema.key_dtype)), jnp.asarray(v9)
    )
    want1 = [8, 12, 8, 0, 0, 0, 0, 0]
    np.testing.assert_array_equal(np.asarray(table.query(refreshed, q)), want1)
    r = table.retrieve(refreshed, q)
    assert int(r.num_dropped) == 0
    per_q = retrieval_to_lists(r)
    assert sorted(_value_rows(np.asarray(per_q[0]))) == sorted(_value_rows(v9))
    assert sorted(_value_rows(np.asarray(per_q[1]))) == sorted(
        _value_rows(v0)[8:16] + _value_rows(v1)[4:8]
    )

    # full compact preserves the lane and the answers
    compacted = refreshed.compact()
    assert compacted.base.local.fingerprints is not None
    np.testing.assert_array_equal(np.asarray(table.query(compacted, q)), want1)


def test_fingerprint_default_by_schema(mesh1):
    """Auto default: multi-lane keys get the lane, 1-lane keys skip it;
    explicit override wins either way."""
    for schema, want in [(TableSchema("uint32", 1), False), (TableSchema("uint64", 1), True)]:
        t = DistributedHashTable(mesh1, ("d",), hash_range=256, schema=schema)
        assert t.use_fingerprint is want
        rng = np.random.default_rng(0)
        keys = _pack(schema, rng.integers(0, 1 << 16, 64).astype(schema.key_dtype))
        st = t.init(keys)
        assert (st.base.local.fingerprints is not None) is want
    t = DistributedHashTable(
        mesh1, ("d",), hash_range=256, schema=TableSchema("uint32", 1), fingerprint=True
    )
    assert t.use_fingerprint is True


def test_collective_budget_with_fingerprints(mesh8):
    """Fused 2-all-to-all budget holds with the fingerprint lane on: the
    routed fingerprints are derived owner-side, never exchanged."""
    schema = TableSchema("uint64", 2)
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 12, schema=schema, max_deltas=8
    )
    assert table.use_fingerprint
    rng = np.random.default_rng(5)

    def keys(n):
        return _pack(schema, rng.integers(0, 1 << 40, n).astype(np.uint64))

    state = table.init(keys(512), values=jnp.asarray(_values_for(schema, 0, 512)))
    for _ in range(3):
        state = state.insert(keys(64), values=jnp.asarray(_values_for(schema, 0, 64)))
    state = state.delete(keys(16))
    assert state.base.local.fingerprints is not None

    q = keys(128)
    jx = jax.make_jaxpr(
        lambda s, qq: plans.exec_retrieve(
            table, s, qq, out_capacity=2048, seg_capacity=2048
        )
    )(state, q)
    assert count_primitive(jx.jaxpr, "all_to_all") == 2
    jq = jax.make_jaxpr(lambda s, qq: plans.exec_query(table, s, qq))(state, q)
    assert count_primitive(jq.jaxpr, "all_to_all") == 2
