"""Observability layer — registry, exporters, tracing, device-cost accounting.

Four layers under test:

* **Registry semantics**: get-or-create identity, one-snapshot
  consistency, histogram quantiles off log buckets (interpolated,
  clamped to observed min/max), type-conflict rejection.
* **Exporters**: the Prometheus text render must round-trip through the
  scrape-side parser (the same path the CI smoke gates use), and the
  JSONL render must emit one valid JSON object per metric with the stamp
  merged in.
* **Tracing**: phase marks -> durations, the bounded ring, the
  ``live()`` leak detector, and the disabled-tracer fast path.
* **Accounting + integration** (mesh): the jaxpr collective accountant
  independently re-confirms the fused two-all-to-all budget at every
  delta depth; ``TableServer.stats()`` is a registry view (no parallel
  counters to drift); the AOT warmup hit/miss discipline is asserted
  through the *metrics API* on a mixed bucket/insert/fold stream; the
  KV cache and maintenance fold recorder feed the same registry.
"""
import json

import numpy as np
import pytest

from repro.core import maintenance, plans
from repro.core.table import DistributedHashTable
from repro.obs import (
    PHASES,
    MetricsRegistry,
    Tracer,
    collective_profile,
    parse_prometheus,
    profile_executor,
    render_jsonl,
    render_prometheus,
)
from repro.serve_table import (
    AsyncFrontend,
    CompactionPolicy,
    MicroBatcher,
    TableServer,
)

# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_counter_monotone_and_get_or_create():
    reg = MetricsRegistry()
    c1 = reg.counter("requests_total", help="x")
    c2 = reg.counter("requests_total")
    assert c1 is c2  # get-or-create: same instrument
    c1.inc()
    c1.inc(4)
    assert c2.value == 5
    with pytest.raises(ValueError):
        c1.inc(-1)
    # Distinct label sets are distinct instruments under one name.
    a = reg.counter("by_kind_total", labels={"kind": "a"})
    b = reg.counter("by_kind_total", labels={"kind": "b"})
    assert a is not b
    a.inc(2)
    snap = reg.snapshot()
    assert snap.value("by_kind_total", {"kind": "a"}) == 2
    assert snap.value("by_kind_total", {"kind": "b"}) == 0
    assert snap.value("absent_total", default=-1) == -1


def test_type_conflict_rejected():
    reg = MetricsRegistry()
    reg.counter("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")


def test_gauge_set_add():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(3)
    g.add(2)
    assert reg.snapshot().value("depth") == 5


def test_histogram_quantiles_single_value():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds")
    h.observe(0.017)
    s = h.snapshot()
    # One observation: every quantile clamps to that value.
    assert s.count == 1
    assert s.p50 == pytest.approx(0.017)
    assert s.p99 == pytest.approx(0.017)
    assert s.p999 == pytest.approx(0.017)
    assert s.mean == pytest.approx(0.017)


def test_histogram_quantiles_spread():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds")
    vals = [0.001] * 98 + [0.5, 1.0]
    for v in vals:
        h.observe(v)
    s = h.snapshot()
    assert s.count == 100
    assert s.sum == pytest.approx(sum(vals))
    assert s.min == pytest.approx(0.001)
    assert s.max == pytest.approx(1.0)
    # p50 sits in the 1ms bucket; p999 reaches into the tail.
    assert s.p50 == pytest.approx(0.001, rel=0.5)
    assert s.p999 >= 0.5
    assert s.quantile(1.0) == pytest.approx(1.0)


def test_histogram_custom_bounds_sorted():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="sorted"):
        reg.histogram("bad", bounds=(2.0, 1.0))


def test_snapshot_is_atomic_view():
    reg = MetricsRegistry()
    c = reg.counter("a_total")
    h = reg.histogram("b_seconds")
    c.inc(7)
    h.observe(0.25)
    snap = reg.snapshot()
    c.inc(100)  # after the sample: must not leak into it
    h.observe(9.0)
    assert snap.value("a_total") == 7
    assert snap.histogram("b_seconds").count == 1
    d = snap.as_dict()
    assert d["a_total"] == 7
    assert d["b_seconds"]["count"] == 1


def test_snapshot_labels_of_and_nested_dict():
    reg = MetricsRegistry()
    reg.counter("folds_total", labels={"kind": "fold"}).inc(3)
    reg.counter("folds_total", labels={"kind": "full"}).inc(1)
    snap = reg.snapshot()
    kinds = {lab["kind"] for lab in snap.labels_of("folds_total")}
    assert kinds == {"fold", "full"}
    assert snap.as_dict()["folds_total"] == {"kind=fold": 3, "kind=full": 1}


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------


def test_prometheus_round_trip():
    reg = MetricsRegistry()
    reg.counter("reqs_total", help="Requests.").inc(42)
    reg.gauge("depth").set(3)
    h = reg.histogram("lat_seconds", labels={"phase": "device"})
    for v in (0.001, 0.004, 0.25):
        h.observe(v)
    text = render_prometheus(reg)
    assert "# HELP reqs_total Requests." in text
    assert "# TYPE lat_seconds histogram" in text
    scraped = parse_prometheus(text)
    assert scraped[("reqs_total", ())] == 42
    assert scraped[("depth", ())] == 3
    assert scraped[("lat_seconds_count", (("phase", "device"),))] == 3
    assert scraped[("lat_seconds_sum", (("phase", "device"),))] == pytest.approx(
        0.255
    )
    # Cumulative buckets: monotone, +Inf bucket equals the count.
    buckets = sorted(
        (dict(lk)["le"], v)
        for (name, lk) in scraped
        if name == "lat_seconds_bucket"
        for v in [scraped[(name, lk)]]
    )
    assert scraped[("lat_seconds_bucket", (("le", "+Inf"), ("phase", "device")))] == 3
    cums = [
        v
        for (name, lk), v in scraped.items()
        if name == "lat_seconds_bucket"
    ]
    assert max(cums) == 3
    assert buckets  # at least one finite bucket rendered


def test_jsonl_render_stamped(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a_total").inc(2)
    reg.histogram("b_seconds").observe(0.5)
    out = render_jsonl(reg, run="unit", ts=123)
    recs = [json.loads(line) for line in out.strip().splitlines()]
    assert {r["metric"] for r in recs} == {"a_total", "b_seconds"}
    assert all(r["run"] == "unit" and r["ts"] == 123 for r in recs)
    hist = next(r for r in recs if r["metric"] == "b_seconds")
    assert hist["count"] == 1 and hist["p50"] == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# Tracing
# ---------------------------------------------------------------------------


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_tracer_phases_histograms_and_ring():
    clock = FakeClock()
    reg = MetricsRegistry()
    tr = Tracer(reg, ring=2, clock=clock)
    for i in range(3):
        clock.t = i * 1.0
        t = tr.start(size=4)
        assert tr.live() == 1
        for j, phase in enumerate(PHASES):
            t.mark(phase, i * 1.0 + 0.01 * (j + 1))
        tr.finish(t)
        assert tr.live() == 0
    snap = reg.snapshot()
    for phase in PHASES:
        h = snap.histogram("trace_phase_seconds", {"phase": phase})
        assert h.count == 3
        assert h.p50 == pytest.approx(0.01, rel=1e-6)
    total = snap.histogram("request_latency_seconds")
    assert total.count == 3
    assert total.p50 == pytest.approx(0.05, rel=1e-6)
    assert snap.value("traces_recorded_total") == 3
    # Ring is bounded: only the 2 most recent traces survive.
    recent = tr.recent()
    assert [t.trace_id for t in recent] == [1, 2]


def test_trace_durations_contiguous_and_clamped():
    clock = FakeClock()
    tr = Tracer(MetricsRegistry(), clock=clock)
    t = tr.start()
    t.mark("admission", 0.1)
    t.mark("linger", 0.3)
    t.mark("dispatch", 0.2)  # clock skew: must clamp, not go negative
    d = t.durations()
    assert d["admission"] == pytest.approx(0.1)
    assert d["linger"] == pytest.approx(0.2)
    assert d["dispatch"] == 0.0
    assert t.total == pytest.approx(0.3)
    assert "device" not in d  # unmarked phases are absent, not zero


def test_tracer_abandon_and_disabled(tmp_path):
    reg = MetricsRegistry()
    tr = Tracer(reg, enabled=True)
    t = tr.start()
    tr.abandon(t)
    assert tr.live() == 0
    assert reg.snapshot().value("traces_recorded_total") == 0  # not recorded
    off = Tracer(MetricsRegistry(), enabled=False)
    assert off.start() is None
    off.finish(None)  # no-ops, no raise
    off.abandon(None)
    # dump_jsonl appends completed traces.
    t2 = tr.start(size=2)
    t2.mark("admission", t2.t0 + 0.001)
    tr.finish(t2)
    path = tmp_path / "traces.jsonl"
    assert tr.dump_jsonl(str(path)) == 1
    rec = json.loads(path.read_text().strip())
    assert rec["size"] == 2 and "admission" in rec["phases"]


# ---------------------------------------------------------------------------
# The shared CI gate
# ---------------------------------------------------------------------------


def test_assert_clean_run_gate():
    from benchmarks.common import assert_clean_run

    reg = MetricsRegistry()
    assert_clean_run(reg.snapshot())  # all-absent metrics default to 0
    reg.counter("aot_misses_total").inc()
    with pytest.raises(AssertionError, match="fell off the warmed"):
        assert_clean_run(reg.snapshot(), context="unit")
    reg2 = MetricsRegistry()
    reg2.gauge("jit_dispatch_cache_size").set(7)
    with pytest.raises(AssertionError, match="cache grew"):
        assert_clean_run(reg2.snapshot(), baseline_cache_size=5)


# ---------------------------------------------------------------------------
# Maintenance fold recorder
# ---------------------------------------------------------------------------


def test_record_fold_metrics_and_clamp():
    maintenance.record_fold(
        None, kind="fold", seconds=0.1, rows_before=10, rows_after=5
    )  # metrics=None: no-op
    reg = MetricsRegistry()
    maintenance.record_fold(
        reg, kind="fold", seconds=0.02, rows_before=100, rows_after=60
    )
    maintenance.record_fold(
        reg, kind="full", seconds=0.2, rows_before=60, rows_after=90
    )  # grew: reclaimed clamps to 0
    snap = reg.snapshot()
    assert snap.value("maintenance_folds_total", {"kind": "fold"}) == 1
    assert snap.value("maintenance_folds_total", {"kind": "full"}) == 1
    assert snap.histogram(
        "maintenance_fold_seconds", {"kind": "fold"}
    ).sum == pytest.approx(0.02)
    assert snap.value("maintenance_reclaimed_rows_total") == 40
    assert snap.value("maintenance_last_reclaimed_rows") == 0


# ---------------------------------------------------------------------------
# Collective accountant (mesh)
# ---------------------------------------------------------------------------


def _small_table(mesh8, **kw):
    kw.setdefault("hash_range", 1 << 12)
    kw.setdefault("max_deltas", 4)
    kw.setdefault("tombstone_capacity", 256)
    return DistributedHashTable(mesh8, ("d",), **kw)


def test_accountant_reconfirms_two_all_to_alls_at_every_depth(mesh8):
    """The acceptance criterion: jaxpr accounting of the fused read path
    must show exactly 2 all-to-alls regardless of delta depth."""
    table = _small_table(mesh8)
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 1 << 12, size=256, dtype=np.uint32)
    state = table.init(keys, np.arange(256, dtype=np.int32))
    queries = plans._proto_queries(table, 16)
    for depth in range(3):
        counts, bytes_ = collective_profile(
            lambda s, q: plans.exec_query(table, s, q), state, queries
        )
        assert counts.get("all_to_all", 0) == 2, (
            f"depth {depth}: fused query budget broken: {counts}"
        )
        assert bytes_["all_to_all"] > 0
        state = state.insert(
            np.array([1, 2, 3, 4, 5, 6, 7, 8], dtype=np.uint32),
            np.arange(8, dtype=np.int32),
        )


def test_profile_executor_query_and_retrieve(mesh8):
    table = _small_table(mesh8)
    keys = np.arange(64, dtype=np.uint32)
    state = table.init(keys, np.arange(64, dtype=np.int32))
    queries = plans._proto_queries(table, 16)
    cost = profile_executor(table, state, queries, kind="query")
    assert cost.kind == "query" and cost.bucket == 16 and cost.depth == 0
    assert cost.all_to_alls == 2
    assert cost.all_to_all_bytes > 0
    assert cost.total_collective_bytes >= cost.all_to_all_bytes
    r = profile_executor(
        table,
        state,
        queries,
        kind="retrieve",
        exec_kwargs={"out_capacity": 64, "seg_capacity": 64},
    )
    assert r.kind == "retrieve" and r.all_to_alls == 2
    d = r.as_dict()
    assert d["all_to_alls"] == 2 and d["collective_counts"]["all_to_all"] == 2


# ---------------------------------------------------------------------------
# Server / frontend / cache integration (mesh)
# ---------------------------------------------------------------------------


def test_server_stats_is_registry_view(mesh8):
    table = _small_table(mesh8)
    rng = np.random.default_rng(5)
    seed = (rng.choice(1 << 14, size=128, replace=False) + 1).astype(np.uint32)
    server = TableServer(
        table,
        seed,
        policy=CompactionPolicy(max_delta_depth=2, fold_k=1),
        batcher=MicroBatcher(table, min_bucket=8),
        write_bucket=8,
    )
    server.query_many([seed[:4]])
    server.query_many([seed[4:8], seed[8:12]])
    server.submit_insert(np.array([9991, 9992], dtype=np.uint32))
    server.step()
    server.submit_insert(np.array([9993], dtype=np.uint32))
    server.step()
    server.submit_insert(np.array([9994], dtype=np.uint32))
    server.step()  # policy folds before applying the third delta
    st = server.stats()
    snap = server.metrics()
    assert st.reads == snap.value("serve_reads_total") == 3
    assert st.read_batches == snap.value("serve_read_batches_total") == 2
    assert st.writes_applied == snap.value("serve_writes_applied_total") == 3
    assert st.folds == snap.value("maintenance_folds_total", {"kind": "fold"})
    assert st.folds >= 1
    assert st.fold_seconds_total == pytest.approx(
        snap.histogram("maintenance_fold_seconds", {"kind": "fold"}).sum
    )
    assert st.batcher.requests == snap.value("batch_requests_total")
    # Refreshed state gauges land in the same sample.
    assert snap.value("serve_seqno") == server.registry.seqno
    assert snap.value("serve_delta_depth") == len(server._shadow.deltas)
    assert snap.value("serve_dropped_rows") == 0
    assert snap.value("jit_dispatch_cache_size") == plans.exec_query._cache_size()
    # The whole sample renders and scrapes.
    scraped = parse_prometheus(render_prometheus(snap))
    assert scraped[("serve_reads_total", ())] == 3


def test_warmup_hit_miss_through_metrics_api(mesh8):
    """Satellite: AOT warmup coverage asserted via the metrics API — a
    mixed bucket/insert/fold stream against a warmed server must show
    aot_hits_total > 0, aot_misses_total == 0, and a flat jit cache."""
    table = _small_table(mesh8, hash_range=1 << 16, max_deltas=3)
    rng = np.random.default_rng(3)
    seed_keys = (rng.choice(1 << 18, size=256, replace=False) + 1000).astype(
        np.uint32
    )
    server = TableServer(
        table,
        seed_keys,
        policy=CompactionPolicy(max_delta_depth=2, fold_k=1, tombstone_load=0.9),
        batcher=MicroBatcher(table, min_bucket=8),
        write_bucket=8,
    )
    warm = server.warm(
        buckets=(8, 16), depths=(0, 1, 2), fold_horizon=1,
        retrieve_caps={8: (64, 64)},
    )
    assert warm.entries > 0
    snap0 = server.metrics()
    assert snap0.value("aot_entries") == warm.entries
    assert snap0.value("aot_misses_total") == 0
    jit0 = snap0.value("jit_dispatch_cache_size")
    # Warmup profiling surfaced per-executor collective gauges at every
    # warmed depth, each inside the fused 2-all-to-all budget.
    depths_profiled = set()
    for labels in snap0.labels_of("executor_all_to_alls"):
        assert snap0.value("executor_all_to_alls", labels) == 2
        depths_profiled.add(int(labels["depth"]))
    assert depths_profiled == {0, 1, 2}
    assert warm.profiles and all(p.all_to_alls == 2 for p in warm.profiles)

    def q(keys):
        res, _ = server.query_many([np.asarray(keys, dtype=np.uint32)])
        return res[0]

    # Mixed stream: both warmed buckets, writes, a delete, one fold.
    assert q(seed_keys[:5]).tolist() == [1] * 5  # bucket 8
    assert q(seed_keys[:12]).tolist() == [1] * 12  # bucket 16
    server.submit_insert(np.array([21, 22], dtype=np.uint32))
    server.step()
    assert q([21, 22, 23]).tolist() == [1, 1, 0]
    server.submit_insert(np.array([24], dtype=np.uint32))
    server.step()
    server.submit_delete(np.array([22], dtype=np.uint32))
    server.step()
    server.submit_insert(np.array([25], dtype=np.uint32))
    server.step()  # policy folds (depth 2 -> 1): fold step 1
    assert q([21, 24, 25]).tolist() == [1, 1, 1]
    vals, _ = server.retrieve_many([np.array([21, 25], dtype=np.uint32)])
    assert [len(v) for v in vals[0]] == [1, 1]

    snap = server.metrics()
    assert snap.value("aot_hits_total") > 0
    assert snap.value("aot_misses_total") == 0, (
        "live traffic fell off the warmed grid"
    )
    assert snap.value("jit_dispatch_cache_size") == jit0, (
        "a live request traced/compiled despite AOT warmup"
    )
    assert snap.value("maintenance_folds_total", {"kind": "fold"}) == 1
    assert snap.histogram("maintenance_fold_seconds", {"kind": "fold"}).count == 1
    # Registry-backed ServerStats agrees with the raw counters.
    st = server.stats()
    assert st.warmup.aot_misses == 0 and st.warmup.aot_hits > 0


def test_frontend_tracing_end_to_end(mesh8):
    table = _small_table(mesh8)
    rng = np.random.default_rng(9)
    seed = (rng.choice(1 << 11, size=64, replace=False) + 1).astype(np.uint32)
    server = TableServer(
        table,
        seed,
        policy=CompactionPolicy(max_delta_depth=3, fold_k=1),
        batcher=MicroBatcher(table, min_bucket=8),
        write_bucket=8,
    )
    with AsyncFrontend(server, linger=0.001, flush_keys=8, trace_ring=16) as fe:
        futs = [fe.submit_query(seed[i : i + 4], timeout=10) for i in range(6)]
        for f in futs:
            assert np.asarray(f.result(timeout=60).counts).tolist() == [1] * 4
        fe_snap = fe.metrics()
    assert fe.tracer.live() == 0
    assert fe_snap.value("trace_live") == 0
    assert fe_snap.value("traces_recorded_total") == 6
    assert fe_snap.value("frontend_completed_total") == 6
    assert fe_snap.value("frontend_failed_total") == 0
    for phase in PHASES:
        h = fe_snap.histogram("trace_phase_seconds", {"phase": phase})
        assert h is not None and h.count == 6, f"phase {phase} not recorded"
    assert fe_snap.histogram("request_latency_seconds").count == 6
    recent = fe.tracer.recent()
    assert recent and all(set(t.marks) == set(PHASES) for t in recent)
    assert all(t.bucket == 8 and t.seqno >= 0 for t in recent)
    # FrontendStats is the same snapshot, viewed per-instance.
    st = fe.stats()
    assert st.submitted == st.completed == 6 and st.failed == 0
    # A second frontend on the same server starts its view at zero.
    fe2 = AsyncFrontend(server, linger=0.001, flush_keys=8)
    assert fe2.stats().submitted == 0


def test_frontend_tracing_disabled_records_nothing(mesh8):
    table = _small_table(mesh8)
    seed = np.arange(1, 65, dtype=np.uint32)
    server = TableServer(
        table,
        seed,
        batcher=MicroBatcher(table, min_bucket=8),
        write_bucket=8,
    )
    with AsyncFrontend(
        server, linger=0.001, flush_keys=8, tracing=False
    ) as fe:
        fut = fe.submit_query(seed[:4], timeout=10)
        assert np.asarray(fut.result(timeout=60).counts).tolist() == [1] * 4
        snap = fe.metrics()
    assert snap.value("traces_recorded_total") == 0
    # Instruments exist (pre-registered) but nothing was observed.
    assert snap.histogram("trace_phase_seconds", {"phase": "device"}).count == 0
    assert snap.value("frontend_completed_total") == 1


def test_kvcache_metrics(mesh8):
    from repro.cache.kvcache import KVCache

    table = _small_table(mesh8)
    cache = KVCache(table, default_ttl=4)
    k = np.array([11, 22, 33, 44, 55, 66, 77, 88], dtype=np.uint32)
    cache.put(k, np.arange(8, dtype=np.int32))
    assert cache.get(k[:2]).tolist() == [0, 1]
    assert cache.contains(k[:1]).tolist() == [True]
    cache.delete(k[:1])
    cache.tick(10)  # everything expires
    reclaimed = cache.evict_expired()
    assert reclaimed >= 0
    snap = cache.metrics()
    assert snap.value("kvcache_puts_total") == 1
    assert snap.value("kvcache_gets_total") == 2  # get + contains
    assert snap.value("kvcache_deletes_total") == 1
    assert snap.value("kvcache_evictions_total") >= 1
    assert snap.histogram("kvcache_put_seconds").count == 1
    assert snap.histogram("kvcache_get_seconds").count == 1
    assert snap.value("kvcache_now") == cache.now == 10
    assert snap.value("kvcache_delta_depth") == 0  # compacted
    # The shared fold recorder fed the same registry.
    assert snap.value("maintenance_folds_total", {"kind": "full"}) >= 1
    assert cache.evictions == snap.value("kvcache_evictions_total")
