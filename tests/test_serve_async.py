"""Async serving front end — concurrency stress, fault injection, deadlines.

The async layer is threads + deadlines + shared snapshots, so these tests
are the PR's backbone rather than an afterthought:

* **Stress**: N reader threads hammer an :class:`AsyncFrontend` while a
  writer streams inserts/deletes and folds run in the background, for a
  wall-clock budget.  Consistency is checked on *every* response via a
  uniform-multiplicity probe set: each write batch inserts the whole set
  exactly once, so any consistent snapshot shows one count for all probe
  keys — a torn read is a non-uniform response, a stale-vs-future mix is
  a count regression across seqnos, and same-seqno responses must agree.
  No response may be lost or duplicated, and shutdown must leave zero
  threads behind.
* **Fault injection**: a writer-loop step or a background fold that
  raises mid-batch must surface on ``stats()``/``drain()`` (never hang),
  leave the published snapshot at the last good seqno, and keep the read
  path serving.
* **Deadline batcher property**: for random arrival schedules, every
  request is dispatched exactly once, by ``min(enqueue + linger,
  deadline)`` (+ one poll step), in a batch bounded by ``flush_keys`` —
  under a fake clock (deterministic) and the real timer.  Runs under
  Hypothesis when installed, otherwise over seeded random schedules.
* **No-retrace regression**: after AOT warmup, a mixed stream across all
  warmed bucket sizes — interleaved with inserts, deletes, a fold, and
  the snapshot swaps they publish — leaves both the executor grid's miss
  counter and ``jax.jit``'s compiled-function cache unchanged.
* **drain() contract**: timeout raises with the number of still-pending
  batches; ``stop()`` (or a dead writer) unblocks waiters promptly.
"""
import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.core import plans
from repro.core.table import DistributedHashTable
from repro.serve_table import (
    AsyncFrontend,
    CompactionPolicy,
    DeadlineBatcher,
    MicroBatcher,
    TableServer,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: seeded-random fallback
    HAVE_HYPOTHESIS = False

# Probe set: one write batch inserts ALL of these exactly once, so every
# consistent snapshot shows a single count c for the whole set (c = number
# of applied probe batches).  Sized to the write bucket so a probe insert
# is exactly one delta.
PROBES = np.array(
    [101, 202, 303, 404, 505, 606, 707, 808], dtype=np.uint32
)
WRITE_BUCKET = 8


def _make_server(
    mesh8, *, policy=None, write_bucket=WRITE_BUCKET, seed=0, pool=256
):
    table = DistributedHashTable(
        mesh8,
        ("d",),
        hash_range=1 << 16,
        max_deltas=4,
        tombstone_capacity=256,
    )
    rng = np.random.default_rng(seed)
    # Seed keys disjoint from PROBES (probe counts must start at 0).
    keys = (rng.choice(1 << 18, size=pool, replace=False) + 1000).astype(
        np.uint32
    )
    server = TableServer(
        table,
        keys,
        policy=policy
        or CompactionPolicy(max_delta_depth=2, fold_k=1, tombstone_load=0.9),
        batcher=MicroBatcher(table, min_bucket=8),
        write_bucket=write_bucket,
    )
    return server, keys


# ---------------------------------------------------------------------------
# Concurrency stress: readers + writer + background folds
# ---------------------------------------------------------------------------


def _run_stress(server, pool, *, budget: float, readers: int, min_responses: int):
    """Hammer the front end; return (responses, errors, frontend stats).

    Write pattern: every writer cycle inserts the whole probe set AND
    deletes 8 fresh seed keys, keeping the live row count constant — so
    the always-escalating compaction policy rebuilds the base to the SAME
    capacity each time and the state-structure family the readers see is
    finite (depth 0..2 over two base shapes).  After the first cycle's
    one-time compiles, reads run at cache speed and the stress actually
    stresses concurrency instead of the compiler.
    """
    stop = threading.Event()
    errors: list = []
    responses: list = []  # (seqno, uniform count) per completed read
    resp_lock = threading.Lock()

    fe = AsyncFrontend(
        server, linger=0.001, flush_keys=WRITE_BUCKET, write_backlog=32
    ).start()

    def reader():
        while not stop.is_set():
            try:
                fut = fe.submit_query(PROBES, timeout=10)
                r = fut.result(timeout=120)
            except Exception as e:  # noqa: BLE001 - recorded for the assert
                errors.append(f"reader: {type(e).__name__}: {e}")
                return
            c = np.asarray(r.counts)
            if c.shape[0] != PROBES.shape[0] or not (c == c[0]).all():
                errors.append(
                    f"torn read at seqno {r.seqno}: {c.tolist()}"
                )
                return
            with resp_lock:
                responses.append((r.seqno, int(c[0])))

    def writer():
        i = 0
        max_cycles = pool.shape[0] // WRITE_BUCKET  # never re-delete a key
        while not stop.is_set():
            if i >= max_cycles:
                time.sleep(0.005)
                continue
            try:
                fe.submit_insert(PROBES, timeout=10)
                # Delete exactly as many (unique, live) seed keys as the
                # probe insert added: live count — and with it the full
                # compact's rebuilt base shape — stays constant.
                fe.submit_delete(
                    pool[i * WRITE_BUCKET : (i + 1) * WRITE_BUCKET], timeout=10
                )
                if i % 10 == 9 and not server.fold_in_flight:
                    try:
                        server.fold_async()  # background compaction
                    except RuntimeError:
                        pass  # raced another fold: fine
            except Exception as e:  # noqa: BLE001
                errors.append(f"writer: {type(e).__name__}: {e}")
                return
            i += 1
            time.sleep(0.005)

    threads = [threading.Thread(target=reader, daemon=True) for _ in range(readers)]
    threads.append(threading.Thread(target=writer, daemon=True))
    t0 = time.monotonic()
    for t in threads:
        t.start()
    # Run for the wall budget, extended (bounded) until enough responses
    # landed that the consistency assertions have teeth — the first write
    # cycle pays one-time plan compiles on this unwarmed server.
    hard_cap = t0 + max(budget * 30, 120.0)
    while time.monotonic() < t0 + budget or (
        len(responses) < min_responses and time.monotonic() < hard_cap
    ):
        if errors:
            break
        time.sleep(0.01)
    stop.set()
    for t in threads:
        t.join(timeout=120)
        assert not t.is_alive(), "stress worker failed to stop"
    server.drain(timeout=180)
    fe.stop()
    while server.fold_in_flight:
        time.sleep(0.005)
    return responses, errors, fe.stats()


def _assert_stress_invariants(responses, errors, stats):
    assert not errors, errors[:5]
    # No lost or duplicated responses: every admitted read resolved once.
    assert stats.failed == 0
    assert stats.completed == stats.submitted
    assert stats.queue_depth == 0 and stats.inflight == 0
    # Per-seqno consistency: same-seqno responses must agree on the count.
    by_seqno: dict = {}
    for seqno, count in responses:
        if seqno in by_seqno:
            assert by_seqno[seqno] == count, (
                f"seqno {seqno} served two different counts "
                f"({by_seqno[seqno]} vs {count})"
            )
        else:
            by_seqno[seqno] = count
    # Monotonicity: probe inserts only accumulate (deletes never touch the
    # probe set), so counts ordered by seqno never regress.
    ordered = sorted(by_seqno.items())
    counts = [c for _, c in ordered]
    assert counts == sorted(counts), f"count regression across seqnos: {ordered}"


# Full compacts only (fold_k == max_delta_depth escalates every trigger):
# the rebuilt base is live-count-sized, and the stress writer keeps the
# live count constant, so the structure family stays finite.
_STRESS_POLICY = CompactionPolicy(
    max_delta_depth=2, fold_k=2, tombstone_load=0.95
)


def test_stress_readers_writer_folds_short(mesh8):
    """CI-budget stress: 3 readers + writer + folds, ~2s of wall traffic."""
    server, pool = _make_server(mesh8, policy=_STRESS_POLICY, pool=4096)
    responses, errors, stats = _run_stress(
        server, pool, budget=2.0, readers=3, min_responses=50
    )
    _assert_stress_invariants(responses, errors, stats)
    assert len(responses) >= 50
    # Clean shutdown: no serving thread survived stop().
    leaked = {
        t
        for t in threading.enumerate()
        if t.is_alive()
        and t.name.startswith(("serve-table", "serve-frontend"))
    }
    assert not leaked, f"leaked serving threads: {leaked}"


@pytest.mark.slow
def test_stress_readers_writer_folds_long(mesh8):
    """Full-budget stress (slow): more readers, longer wall clock."""
    server, pool = _make_server(
        mesh8, policy=_STRESS_POLICY, seed=7, pool=16384
    )
    responses, errors, stats = _run_stress(
        server, pool, budget=8.0, readers=5, min_responses=300
    )
    _assert_stress_invariants(responses, errors, stats)
    assert len(responses) >= 300


# ---------------------------------------------------------------------------
# Deterministic fault injection
# ---------------------------------------------------------------------------


def test_writer_crash_surfaces_and_reads_survive(mesh8, monkeypatch):
    server, _ = _make_server(mesh8, seed=1)
    table = server.table
    seed_key = np.array([42, 43], dtype=np.uint32)
    real_insert = table.insert
    poison = {"armed": False}

    def flaky_insert(state, keys, values=None, **kw):
        if poison["armed"]:
            raise RuntimeError("injected insert failure")
        return real_insert(state, keys, values, **kw)

    monkeypatch.setattr(table, "insert", flaky_insert)
    server.start()
    try:
        server.submit_insert(seed_key)  # applies fine -> seqno 1
        server.drain(timeout=60)
        good_seqno = server.registry.seqno
        assert good_seqno >= 1

        poison["armed"] = True
        server.submit_insert(np.array([77], dtype=np.uint32))
        server.submit_insert(np.array([78], dtype=np.uint32))
        # The embedded writer must die loudly, not hang.
        deadline = time.monotonic() + 30
        while server._writer_thread.is_alive() and time.monotonic() < deadline:
            time.sleep(0.005)
        assert not server._writer_thread.is_alive(), "writer loop hung on error"

        stats = server.stats()
        assert stats.last_error and "injected insert failure" in stats.last_error
        # Published snapshot stayed at the last good seqno; the failed
        # batch was re-queued, not dropped.
        assert server.registry.seqno == good_seqno
        assert server.pending() == 2
        # Read path keeps serving the last good snapshot.
        res, seqno = server.query_many([seed_key])
        assert seqno == good_seqno and res[0].tolist() == [1, 1]
        # drain() surfaces the failure instead of hanging or lying: with
        # the embedded writer dead it re-drives step() inline, which
        # re-raises the injected error.
        with pytest.raises(RuntimeError, match="injected insert failure"):
            server.drain(timeout=5)
    finally:
        poison["armed"] = False
        server.stop()


def test_fold_crash_surfaces_and_reads_survive(mesh8, monkeypatch):
    server, _ = _make_server(
        mesh8, policy=CompactionPolicy(max_delta_depth=None), seed=2
    )
    server.submit_insert(np.array([11, 12], dtype=np.uint32))
    server.submit_insert(np.array([13, 14], dtype=np.uint32))
    while server.step():
        pass
    good_seqno = server.registry.seqno
    assert len(server._shadow.deltas) == 2

    def boom(state, k):
        raise RuntimeError("injected fold failure")

    monkeypatch.setattr("repro.core.maintenance.fold_oldest", boom)
    t = server.fold_async(1)
    t.join(timeout=30)
    assert not t.is_alive(), "fold thread hung on error"
    stats = server.stats()
    assert stats.last_error and "injected fold failure" in stats.last_error
    assert server.registry.seqno == good_seqno  # snapshot at last good seqno
    res, seqno = server.query_many([np.array([11, 13], dtype=np.uint32)])
    assert seqno == good_seqno and res[0].tolist() == [1, 1]
    with pytest.raises(RuntimeError, match="background fold failed"):
        server.drain(timeout=5)


# ---------------------------------------------------------------------------
# Deadline batcher: dispatch-exactly-once, deadline bound, bucket bound
# ---------------------------------------------------------------------------

LINGER = 0.01
FLUSH_KEYS = 16


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _check_schedule(schedule):
    """Drive a fake-clock DeadlineBatcher through one arrival schedule.

    ``schedule`` is a list of ``(arrival, size, deadline_offset)``; the
    checker polls at every arrival and every per-request obligation time,
    then asserts the three batching properties.
    """
    clock = FakeClock()
    b = DeadlineBatcher(
        flush_keys=FLUSH_KEYS, linger=LINGER, capacity=10_000, clock=clock
    )
    arrivals = sorted(
        (float(a), int(s), float(d)) for a, s, d in schedule
    )
    eps = 1e-6
    # Poll at every moment something can become due: each arrival, each
    # arrival+linger, each deadline (plus the final drain point).
    times = sorted(
        {a for a, _, _ in arrivals}
        | {a + LINGER + eps for a, _, _ in arrivals}
        | {a + d + eps for a, s, d in arrivals}
    )
    submitted = []  # (_Pending, deadline_abs)
    dispatched = []  # (request, dispatch_time, batch_index)
    it = iter(arrivals)
    nxt = next(it, None)
    batch_idx = 0
    for now in times:
        clock.t = now
        while nxt is not None and nxt[0] <= now + eps:
            a, size, doff = nxt
            req = b.submit(
                np.arange(size, dtype=np.uint32), deadline=a + doff
            )
            submitted.append((req, a + doff))
            nxt = next(it, None)
        while True:
            batch = b.poll(now)
            if batch is None:
                break
            total = sum(r.size for r in batch)
            # Bucket bound: a batch never exceeds flush_keys unless a
            # single oversized request forces it.
            assert total <= FLUSH_KEYS or len(batch) == 1
            for r in batch:
                dispatched.append((r, now, batch_idx))
            batch_idx += 1
    assert b.pending() == 0, "requests left undispatched after final poll"

    # Exactly once.
    ids = [id(r) for r, _, _ in dispatched]
    assert len(ids) == len(set(ids)) == len(submitted)
    # Deadline bound: dispatched by min(enqueue+linger, deadline), within
    # one poll step (we poll exactly at obligation times, so eps slack).
    for r, t_disp, _ in dispatched:
        bound = min(r.enqueued + LINGER, r.deadline)
        assert t_disp <= bound + 2 * eps, (
            f"request enqueued at {r.enqueued} (deadline {r.deadline}) "
            f"dispatched late at {t_disp}"
        )


def _random_schedule(seed: int):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 60))
    return [
        (
            float(rng.uniform(0, 0.05)),
            int(rng.integers(1, 12)),
            float(rng.uniform(0.0005, 0.03)),
        )
        for _ in range(n)
    ]


if HAVE_HYPOTHESIS:

    @settings(max_examples=50, deadline=None)
    @given(
        schedule=st.lists(
            st.tuples(
                st.floats(0, 0.05, allow_nan=False),
                st.integers(1, 12),
                st.floats(0.0005, 0.03, allow_nan=False),
            ),
            min_size=1,
            max_size=60,
        )
    )
    def test_deadline_batcher_property_fake_clock(schedule):
        _check_schedule(schedule)

else:

    @pytest.mark.parametrize("seed", range(25))
    def test_deadline_batcher_property_fake_clock(seed):
        _check_schedule(_random_schedule(seed))


def test_deadline_batcher_real_timer():
    """Same exactly-once/deadline/bucket properties under the real clock."""
    b = DeadlineBatcher(flush_keys=32, linger=0.02, capacity=1024)
    n = 60
    dispatched = []
    done = threading.Event()

    def consumer():
        got = 0
        while got < n:
            batch = b.next_batch(timeout=1.0)
            if batch is None:
                break
            assert sum(r.size for r in batch) <= 32 or len(batch) == 1
            t = time.monotonic()
            dispatched.extend((r, t) for r in batch)
            got += len(batch)
        done.set()

    c = threading.Thread(target=consumer, daemon=True)
    c.start()
    submitted = []
    for i in range(n):
        submitted.append(b.submit(np.arange(1 + i % 4, dtype=np.uint32)))
        time.sleep(0.001)
    assert done.wait(timeout=20), "consumer never drained the queue"
    c.join(timeout=5)

    ids = [id(r) for r, _ in dispatched]
    assert len(ids) == len(set(ids)) == n  # exactly once
    for r, t_disp in dispatched:
        # Real-timer bound: linger plus generous scheduler slack.
        assert t_disp - r.enqueued <= 0.02 + 0.5
    b.close()
    assert b.next_batch(timeout=0.1) is None  # close() wakes and exhausts


def test_deadline_batcher_urgent_deadline_pulls_flush_forward():
    """A later request with an earlier deadline flushes the whole queue."""
    clock = FakeClock()
    b = DeadlineBatcher(flush_keys=64, linger=1.0, capacity=64, clock=clock)
    b.submit(np.arange(2, dtype=np.uint32))  # relaxed: due at t=1.0
    clock.t = 0.1
    b.submit(np.arange(2, dtype=np.uint32), deadline=0.2)  # urgent
    assert b.poll(0.15) is None  # nothing due yet
    batch = b.poll(0.21)  # urgent deadline passed: both ship now
    assert batch is not None and len(batch) == 2


def test_deadline_batcher_backpressure_and_close():
    clock = FakeClock()
    b = DeadlineBatcher(flush_keys=8, linger=1.0, capacity=2, clock=clock)
    b.submit(np.arange(1, dtype=np.uint32))
    b.submit(np.arange(1, dtype=np.uint32))
    with pytest.raises(TimeoutError, match="admission queue full"):
        b.submit(np.arange(1, dtype=np.uint32), timeout=0.05)
    assert b.poll(2.0) is not None  # linger expired: frees capacity
    b.submit(np.arange(1, dtype=np.uint32))  # fits again
    b.close()
    with pytest.raises(RuntimeError, match="closed"):
        b.submit(np.arange(1, dtype=np.uint32))


# ---------------------------------------------------------------------------
# No-retrace regression: warmed grid + mixed stream = zero new compiles
# ---------------------------------------------------------------------------


def test_no_retrace_after_warmup(mesh8):
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 16, max_deltas=3, tombstone_capacity=256
    )
    rng = np.random.default_rng(3)
    seed_keys = (rng.choice(1 << 18, size=256, replace=False) + 1000).astype(
        np.uint32
    )
    server = TableServer(
        table,
        seed_keys,
        policy=CompactionPolicy(max_delta_depth=2, fold_k=1, tombstone_load=0.9),
        batcher=MicroBatcher(table, min_bucket=8),
        write_bucket=8,
    )
    warm = server.warm(
        buckets=(8, 16), depths=(0, 1, 2), fold_horizon=1,
        retrieve_caps={8: (64, 64)},
    )
    assert warm.entries > 0 and warm.aot_misses == 0

    has_counter = hasattr(plans.exec_query, "_cache_size")
    jit_before = plans.exec_query._cache_size() if has_counter else None

    def q(keys):
        res, _ = server.query_many([np.asarray(keys, dtype=np.uint32)])
        return res[0]

    # Mixed open-loop stream: both warmed buckets, interleaved writes (and
    # the snapshot swaps they publish), a delete, and one incremental fold.
    assert q(seed_keys[:5]).tolist() == [1] * 5  # bucket 8
    assert q(seed_keys[:12]).tolist() == [1] * 12  # bucket 16
    server.submit_insert(np.array([21, 22], dtype=np.uint32))
    server.step()  # depth 1, snapshot swap
    assert q([21, 22, 23]).tolist() == [1, 1, 0]
    server.submit_insert(np.array([24], dtype=np.uint32))
    server.step()  # depth 2
    assert q(np.concatenate([[21, 22, 24], seed_keys[:9]])).tolist() == [1] * 12
    server.submit_delete(np.array([22], dtype=np.uint32))
    server.step()
    assert q([21, 22, 24]).tolist() == [1, 0, 1]
    server.submit_insert(np.array([25], dtype=np.uint32))
    server.step()  # policy folds (depth 2 -> 1) before applying: fold step 1
    assert server.stats().folds == 1
    assert q([21, 24, 25]).tolist() == [1, 1, 1]
    assert q(seed_keys[:16]).tolist() == [1] * 16  # bucket 16 post-fold
    # Warmed retrieve path too.
    vals, _ = server.retrieve_many([np.array([21, 25], dtype=np.uint32)])
    assert [len(v) for v in vals[0]] == [1, 1]

    after = server.stats().warmup
    assert after.aot_misses == 0, (
        f"live traffic fell off the warmed grid: {after}"
    )
    assert after.aot_hits >= 8
    if has_counter:
        assert plans.exec_query._cache_size() == jit_before, (
            "a live request traced/compiled despite AOT warmup"
        )


# ---------------------------------------------------------------------------
# drain(): timeout must raise with pending count; stop() must unblock
# ---------------------------------------------------------------------------


def test_drain_timeout_raises_with_pending_count(mesh8):
    server, _ = _make_server(mesh8, seed=4)
    server.submit_insert(np.array([5], dtype=np.uint32))
    # Hold the shadow-mutation mutex: inline step() can't apply anything.
    assert server._writer_mutex.acquire(timeout=5)
    try:
        with pytest.raises(TimeoutError, match="1 pending batch"):
            server.drain(timeout=0.3)
    finally:
        server._writer_mutex.release()
    server.drain(timeout=60)  # mutex free: drains fine now
    assert server.pending() == 0
    assert server.query(np.array([5], dtype=np.uint32)).tolist() == [1]


def test_drain_unblocks_on_stop(mesh8):
    server, _ = _make_server(mesh8, seed=5)
    server.start()
    assert server._writer_mutex.acquire(timeout=5)  # writer loop can't apply
    outcome: list = []

    def drainer():
        try:
            server.drain(timeout=60)
            outcome.append("returned")
        except Exception as e:  # noqa: BLE001 - the outcome under test
            outcome.append(e)

    try:
        server.submit_insert(np.array([6], dtype=np.uint32))
        t = threading.Thread(target=drainer, daemon=True)
        t.start()
        time.sleep(0.2)
        assert t.is_alive()  # parked on the embedded writer
        t0 = time.monotonic()
        server.stop()
        t.join(timeout=10)
        assert not t.is_alive(), "drain stayed blocked after stop()"
        assert time.monotonic() - t0 < 10  # unblocked promptly, not at timeout
        assert len(outcome) == 1 and isinstance(outcome[0], RuntimeError)
        assert "1 pending batch" in str(outcome[0])
    finally:
        server._writer_mutex.release()
        server.stop()


def test_future_results_are_read_your_writes_with_wait_for(mesh8):
    """wait_for(seqno) + submit_query observes a just-applied write."""
    server, _ = _make_server(mesh8, seed=6)
    with AsyncFrontend(server, linger=0.001) as fe:
        fe.submit_insert(np.array([91, 92], dtype=np.uint32))
        server.drain(timeout=60)
        target = server.registry.seqno
        snap = server.registry.wait_for(target, timeout=30)
        assert snap.seqno >= target
        fut = fe.submit_query(np.array([91, 92, 93], dtype=np.uint32))
        r = fut.result(timeout=60)
        assert isinstance(fut, Future)
        assert r.counts.tolist() == [1, 1, 0] and r.seqno >= target
    with pytest.raises(TimeoutError):
        server.registry.wait_for(server.registry.seqno + 1, timeout=0.05)
