"""Multi-device training semantics checks (run with 8 fake host devices).

Covers: GSPMD sharded training, int8-compressed manual DP, elastic
checkpoint restore across mesh shapes, and pipeline parallelism.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.utils.compat import shard_map

from repro.configs.base import get_smoke_config
from repro.data import ShardedLoader, SyntheticCorpus
from repro.distributed.parallel import ParallelConfig, single_device_parallel
from repro.models.api import build_model
from repro.optim.compress import compressed_psum_int8
from repro.train import Trainer, TrainerConfig, TrainStepConfig
from repro.train.manual_dp import make_manual_dp_train_step
from repro.train.pipeline import make_pp_train_step
from repro.train.step import make_train_state


def check(name, cond):
    if not cond:
        print(f"FAIL {name}")
        sys.exit(1)
    print(f"OK {name}")


def gspmd_sharded_training():
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    parallel = ParallelConfig(
        mesh=mesh, dp_axes=("data",), tp_axis="model", microbatches=2
    )
    cfg = get_smoke_config("qwen3_4b")
    bundle = build_model(cfg, parallel)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=32, seed=0)
    loader = ShardedLoader(corpus, batch_size=8, mesh=mesh, dp_axes=("data",))
    tr = Trainer(
        bundle, loader,
        TrainStepConfig(peak_lr=1e-3, warmup_steps=2, total_steps=12),
        TrainerConfig(total_steps=12, log_every=1),
        log_fn=lambda s: None,
    )
    out = tr.run()
    hist = out["history"]
    check("gspmd_loss_decreases", hist[-1]["loss"] < hist[0]["loss"])
    # params actually sharded (embed: vocab on model; d_model deliberately
    # NOT FSDP'd — see sharding.py §Perf iter 1 note)
    emb = tr.params["embed"]
    check("gspmd_params_sharded", emb.sharding.spec[0] == "model")
    wq = jax.tree.leaves(tr.params["layers"])  # some layer leaf is sharded
    check(
        "gspmd_layer_leaves_sharded",
        any(
            any(s is not None for s in l.sharding.spec)
            for l in wq if hasattr(l, "sharding")
        ),
    )
    return hist


def compressed_psum_close_to_exact():
    mesh = jax.make_mesh((8,), ("d",))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((8, 1000)), jnp.float32)

    def body(xl):
        flat = xl.reshape(-1)
        return (
            compressed_psum_int8(flat, ("d",)),
            jax.lax.pmean(flat, ("d",)),
        )

    comp, exact = jax.jit(
        shard_map(body, mesh=mesh, in_specs=(P("d"),), out_specs=(P(), P()),
                  check_vma=False)
    )(x)
    err = float(jnp.max(jnp.abs(comp - exact)))
    scale = float(jnp.max(jnp.abs(exact))) + 1e-9
    check("compressed_psum_close", err / scale < 0.05)


def manual_dp_with_compression():
    mesh = jax.make_mesh((8,), ("data",))
    parallel = ParallelConfig(
        mesh=mesh, dp_axes=("data",), tp_axis=None, grad_compression=True
    )
    cfg = dataclasses.replace(get_smoke_config("qwen3_4b"), num_layers=2)
    bundle = build_model(cfg, parallel)
    tcfg = TrainStepConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    params, opt = make_train_state(bundle, tcfg, jax.random.key(0))
    opt["ef_error"] = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.bfloat16), params
    )
    step = jax.jit(make_manual_dp_train_step(bundle, tcfg))
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=32, seed=1)
    losses = []
    for i in range(8):
        batch = {"tokens": corpus.batch(i, 8)}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    check("manual_dp_finite", np.isfinite(losses).all())
    check("manual_dp_loss_decreases", losses[-1] < losses[0])
    # int8 collectives really on the wire
    hlo = jax.jit(make_manual_dp_train_step(bundle, tcfg)).lower(
        params, opt, {"tokens": corpus.batch(0, 8)}
    ).compile().as_text()
    check("manual_dp_s8_collective", "s8[" in hlo and "all-to-all" in hlo)


def elastic_restore_across_meshes():
    from repro.checkpoint import CheckpointManager
    from repro.distributed import sharding as shd

    cfg = dataclasses.replace(get_smoke_config("qwen3_4b"), num_layers=2)
    tcfg = TrainStepConfig()

    mesh_a = jax.make_mesh((8,), ("data",))
    par_a = ParallelConfig(mesh=mesh_a, dp_axes=("data",), tp_axis=None)
    bundle_a = build_model(cfg, par_a)
    pshapes = bundle_a.param_shapes()
    specs_a = shd.param_pspecs(pshapes, par_a)
    sh_a = shd.to_named(mesh_a, specs_a)
    params_a = jax.jit(bundle_a.init, out_shardings=sh_a)(jax.random.key(7))

    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, async_write=False)
        m.save(1, {"params": params_a})

        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        par_b = ParallelConfig(mesh=mesh_b, dp_axes=("data",), tp_axis="model")
        specs_b = shd.param_pspecs(pshapes, par_b)
        sh_b = shd.to_named(mesh_b, specs_b)
        like = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), pshapes
        )
        _, tree, _ = m.restore({"params": like}, shardings={"params": sh_b})
        params_b = tree["params"]
        same = jax.tree.map(
            lambda a, b: np.allclose(np.asarray(a), np.asarray(b)),
            params_a, params_b,
        )
        check("elastic_restore_values", all(jax.tree.leaves(same)))
        emb_spec = params_b["embed"].sharding.spec
        check("elastic_restore_resharded", emb_spec == specs_b["embed"])


def pipeline_parallel_matches_single_device():
    cfg = dataclasses.replace(
        get_smoke_config("qwen3_4b"), num_layers=4, dtype="float32"
    )
    tcfg = TrainStepConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=16, seed=2)
    batch = {"tokens": corpus.batch(0, 8)}

    # single-device reference loss
    bundle_ref = build_model(cfg, single_device_parallel())
    params = bundle_ref.init(jax.random.key(9))
    loss_ref, _ = bundle_ref.loss(params, batch)

    mesh = jax.make_mesh((2,), ("stage",))
    par = ParallelConfig(mesh=mesh, dp_axes=(), tp_axis=None)
    bundle_pp = build_model(cfg, par)
    from repro.optim import adamw_init

    opt = adamw_init(params, tcfg.adamw)
    step = jax.jit(make_pp_train_step(bundle_pp, tcfg, num_microbatches=4))
    p2, o2, metrics = step(params, opt, batch)
    check(
        "pp_loss_matches_reference",
        abs(float(metrics["loss"]) - float(loss_ref)) < 5e-3,
    )
    losses = [float(metrics["loss"])]
    for i in range(1, 6):
        p2, o2, metrics = step(p2, o2, {"tokens": corpus.batch(i, 8)})
        losses.append(float(metrics["loss"]))
    check("pp_loss_decreases", losses[-1] < losses[0])


def main():
    gspmd_sharded_training()
    compressed_psum_close_to_exact()
    manual_dp_with_compression()
    elastic_restore_across_meshes()
    pipeline_parallel_matches_single_device()
    print("ALL_OK")


if __name__ == "__main__":
    main()
