"""Multi-device HashGraph correctness checks.

Run in a subprocess with fake host devices, e.g.::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python tests/multidevice/run_hashtable_checks.py

Exits non-zero on any failure; prints OK lines per check.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import DistributedHashTable


def check(name, cond):
    if not cond:
        print(f"FAIL {name}")
        sys.exit(1)
    print(f"OK {name}")


def np_counts(build_keys, query_keys):
    c = Counter(build_keys.tolist())
    return np.array([c[int(q)] for q in query_keys], dtype=np.int32)


def main():
    devs = jax.devices()
    assert len(devs) == 8, f"expected 8 fake devices, got {len(devs)}"
    mesh = jax.make_mesh((2, 4), ("x", "y"))
    rng = np.random.default_rng(0)

    # ---- 1. random keys, exact multiset counts ----------------------------
    n = 1 << 13
    hr = n  # C = 1 as in the paper
    keys = rng.integers(0, 1 << 20, size=n, dtype=np.uint32)
    queries = np.concatenate(
        [keys[: n // 2], rng.integers(0, 1 << 20, size=n // 2, dtype=np.uint32)]
    )
    table = DistributedHashTable(mesh, ("x", "y"), hash_range=hr)
    state = table.build(jnp.asarray(keys))
    check("no_capacity_drops", int(state.num_dropped) == 0)
    counts = np.asarray(table.query(state, jnp.asarray(queries)))
    check("random_counts_exact", (counts == np_counts(keys, queries)).all())

    # ---- 2. sequential keys (paper's sequential experiment) ----------------
    keys_seq = np.arange(n, dtype=np.uint32)
    state2 = table.build(jnp.asarray(keys_seq))
    counts2 = np.asarray(table.query(state2, jnp.asarray(keys_seq)))
    check("sequential_all_found_once", (counts2 == 1).all())

    # ---- 3. heavy duplicates (paper §5.4) ----------------------------------
    dup = 64
    base = rng.integers(0, 1 << 18, size=n // dup, dtype=np.uint32)
    keys_dup = np.repeat(base, dup)
    rng.shuffle(keys_dup)
    # generous capacity slack: duplicates concentrate keys on fewer devices
    table_dup = DistributedHashTable(mesh, ("x", "y"), hash_range=hr, capacity_slack=1.5)
    state3 = table_dup.build(jnp.asarray(keys_dup))
    check("dup_no_drops", int(state3.num_dropped) == 0)
    q3 = np.concatenate([base, rng.integers(0, 1 << 18, size=64, dtype=np.uint32)])
    pad = (-len(q3)) % 8
    q3 = np.concatenate([q3, np.full(pad, base[0], np.uint32)])
    counts3 = np.asarray(table_dup.query(state3, jnp.asarray(q3)))
    check("dup_counts_exact", (counts3 == np_counts(keys_dup, q3)).all())

    # ---- 4. join size -------------------------------------------------------
    jsz = int(table.join_size(state, jnp.asarray(queries)))
    check("join_size", jsz == int(np_counts(keys, queries).sum()))

    # ---- 5. paper-faithful probe path matches sorted path -------------------
    table_probe = DistributedHashTable(
        mesh, ("x", "y"), hash_range=hr, paper_faithful_probe=True, max_probe=64
    )
    state5 = table_probe.build(jnp.asarray(keys))
    counts5 = np.asarray(table_probe.query(state5, jnp.asarray(queries)))
    check("probe_matches_sorted", (counts5 == counts).all())

    # ---- 6. load balance: each device holds ~N/D keys ----------------------
    sizes = []
    d = 8
    off_g = np.asarray(state.local.offsets).reshape(d, -1)
    for r in range(d):
        sizes.append(int(off_g[r][table.local_range_cap]))
    sizes = np.array(sizes)
    imbalance = sizes.max() / max(1.0, n / d)
    check("load_balanced<=1.25x", imbalance <= 1.25)
    check("all_keys_distributed", sizes.sum() == n)

    # ---- 7. single-axis mesh (flat 8) ---------------------------------------
    mesh1 = jax.make_mesh((8,), ("d",))
    t1 = DistributedHashTable(mesh1, ("d",), hash_range=hr)
    s1 = t1.build(jnp.asarray(keys))
    c1 = np.asarray(t1.query(s1, jnp.asarray(queries)))
    check("flat_mesh_counts_exact", (c1 == np_counts(keys, queries)).all())

    print("ALL_OK")


if __name__ == "__main__":
    main()
