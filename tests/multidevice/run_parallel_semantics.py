"""Parallel-semantics checks: MoE EP ≡ dense, distributed dedup ≡ local,
sequence-sharded decode ≡ single-device decode (8 fake devices)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_smoke_config
from repro.core.table import DistributedHashTable
from repro.data import dedup_mask, dedup_mask_distributed
from repro.distributed.parallel import ParallelConfig
from repro.distributed import sharding as shd
from repro.models import moe as moe_mod
from repro.models.api import build_model


def check(name, cond):
    if not cond:
        print(f"FAIL {name}")
        sys.exit(1)
    print(f"OK {name}")


def moe_ep_matches_dense():
    """The paper's exchange as MoE dispatch: EP output == dense output."""
    cfg = dataclasses.replace(
        get_smoke_config("mixtral_8x22b"), dtype="float32", num_experts=4,
        moe_capacity_factor=4.0,  # generous: no drops → exact equality
    )
    mesh = jax.make_mesh((8,), ("data",))
    parallel = ParallelConfig(
        mesh=mesh, dp_axes=("data",), tp_axis=None, moe_impl="ep"
    )
    key = jax.random.key(0)
    params = moe_mod.init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(1), (8, 16, cfg.d_model), jnp.float32)

    dense_out, dense_aux = jax.jit(
        lambda p, xx: moe_mod.moe_dense(p, xx, cfg)
    )(params, x)
    ep_out, ep_aux = jax.jit(
        lambda p, xx: moe_mod.moe_ep(p, xx, cfg, parallel)
    )(params, x)
    err = float(jnp.max(jnp.abs(dense_out - ep_out)))
    check("moe_ep_matches_dense", err < 1e-4)
    # aux is pmean of per-shard stats, close but not identical; sanity only
    check("moe_ep_aux_finite", np.isfinite(float(ep_aux)))


def distributed_dedup_matches_local():
    rng = np.random.default_rng(0)
    base = rng.integers(1, 1 << 20, size=(48, 16)).astype(np.int32)
    toks = np.concatenate([base, base[:16]])  # 16 duplicate rows
    local = np.asarray(dedup_mask(jnp.asarray(toks)))

    mesh = jax.make_mesh((8,), ("d",))
    table = DistributedHashTable(mesh, ("d",), hash_range=256)
    dist = np.asarray(dedup_mask_distributed(table, jnp.asarray(toks)))
    check("distributed_dedup_matches_local", (local == dist).all())
    check("dedup_finds_duplicates", (~local).sum() == 16)


def seq_sharded_decode_matches_single():
    """kv_heads=1 cache sequence-sharded over 'model': decode must equal
    the unsharded result (GSPMD inserts the flash-decode style combine)."""
    cfg = dataclasses.replace(
        get_smoke_config("granite_20b"), dtype="float32", num_layers=2
    )
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    parallel = ParallelConfig(mesh=mesh, dp_axes=("data",), tp_axis="model")
    bundle = build_model(cfg, parallel)
    params = bundle.init(jax.random.key(3))

    b, cache_len = 4, 64
    rng = np.random.default_rng(4)
    prompt = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, 8), np.int32))
    logits_p, caches = bundle.prefill(
        params, {"tokens": prompt}, cache_len=cache_len
    )
    tok = jnp.argmax(logits_p, -1).astype(jnp.int32)[:, None]
    pos = jnp.full((b,), 8, jnp.int32)

    ref_logits, _ = jax.jit(bundle.decode_step)(params, caches, tok, pos)

    cache_shapes = jax.eval_shape(lambda: caches)
    cspecs = shd.cache_pspecs(cache_shapes, parallel)
    flat_specs = jax.tree.leaves(cspecs, is_leaf=lambda s: isinstance(s, P))
    has_seq_shard = any(
        len(s) >= 4 and s[3] == "model" for s in flat_specs
    )
    check("granite_cache_seq_sharded", has_seq_shard)
    sh = shd.to_named(mesh, cspecs)
    caches_sharded = jax.tree.map(jax.device_put, caches, sh)
    got_logits, _ = jax.jit(bundle.decode_step)(params, caches_sharded, tok, pos)
    err = float(jnp.max(jnp.abs(got_logits - ref_logits)))
    check("seq_sharded_decode_matches", err < 1e-4)


def main():
    moe_ep_matches_dense()
    distributed_dedup_matches_local()
    seq_sharded_decode_matches_single()
    print("ALL_OK")


if __name__ == "__main__":
    main()
