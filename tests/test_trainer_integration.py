"""Trainer integration: convergence, crash/restart, microbatch equivalence."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.data import ShardedLoader, SyntheticCorpus
from repro.distributed.parallel import single_device_parallel
from repro.models.api import build_model
from repro.train import Trainer, TrainerConfig, TrainStepConfig
from repro.train.trainer import SimulatedFailure


def _mk(arch="qwen3_4b", microbatches=1, seed=0):
    cfg = get_smoke_config(arch)
    parallel = dataclasses.replace(
        single_device_parallel(), microbatches=microbatches
    )
    bundle = build_model(cfg, parallel)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seq_len=32, seed=seed)
    loader = ShardedLoader(corpus, batch_size=4)
    return bundle, loader


def test_loss_decreases():
    bundle, loader = _mk()
    tr = Trainer(
        bundle, loader,
        TrainStepConfig(peak_lr=1e-3, warmup_steps=2, total_steps=30),
        TrainerConfig(total_steps=30, log_every=5),
        log_fn=lambda s: None,
    )
    out = tr.run()
    hist = out["history"]
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_crash_restart_resumes_exactly(tmp_path):
    """Run A: train 20 steps straight. Run B: crash at 12, restart, finish.
    Final losses must match to float tolerance — proves checkpoint +
    loader-step resume reproduce the uninterrupted trajectory."""
    tcfg = TrainStepConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)

    bundle, loader = _mk(seed=11)
    tr_a = Trainer(
        bundle, loader, tcfg,
        TrainerConfig(total_steps=20, log_every=1),
        log_fn=lambda s: None,
    )
    loss_a = tr_a.run()["history"][-1]["loss"]

    bundle, loader = _mk(seed=11)
    ck = str(tmp_path / "ck")
    tr_b1 = Trainer(
        bundle, loader, tcfg,
        TrainerConfig(
            total_steps=20, log_every=1, checkpoint_every=5,
            checkpoint_dir=ck, crash_at_step=12,
        ),
        log_fn=lambda s: None,
    )
    with pytest.raises(SimulatedFailure):
        tr_b1.run()

    bundle, loader = _mk(seed=11)  # fresh process state
    tr_b2 = Trainer(
        bundle, loader, tcfg,
        TrainerConfig(
            total_steps=20, log_every=1, checkpoint_every=5, checkpoint_dir=ck
        ),
        log_fn=lambda s: None,
    )
    assert tr_b2.step == 10  # restored from the step-10 snapshot
    assert loader.state.step == 10
    loss_b = tr_b2.run()["history"][-1]["loss"]
    assert loss_b == pytest.approx(loss_a, rel=1e-4)


def test_microbatched_matches_full_batch():
    """k-microbatch grad accumulation ≈ single large batch (same data)."""
    tcfg = TrainStepConfig(peak_lr=5e-4, warmup_steps=1, total_steps=5)
    losses = {}
    for k in (1, 2):
        bundle, loader = _mk(microbatches=k, seed=3)
        tr = Trainer(
            bundle, loader, tcfg,
            TrainerConfig(total_steps=5, log_every=1),
            log_fn=lambda s: None,
        )
        losses[k] = [h["loss"] for h in tr.run()["history"]]
    np.testing.assert_allclose(losses[1], losses[2], rtol=2e-3, atol=2e-3)


def test_straggler_detector_counts():
    bundle, loader = _mk()
    tr = Trainer(
        bundle, loader,
        TrainStepConfig(total_steps=5),
        TrainerConfig(total_steps=5, log_every=0, straggler_factor=3.0),
        log_fn=lambda s: None,
    )
    # simulate: feed the EWMA directly
    tr._track_stragglers(0.1)
    for _ in range(5):
        tr._track_stragglers(0.1)
    assert tr.straggler_steps == 0
    tr._track_stragglers(1.0)  # 10x the EWMA → flagged
    assert tr.straggler_steps == 1
