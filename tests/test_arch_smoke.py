"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes + finite values (assignment f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPE_SUITE, get_config, get_smoke_config
from repro.distributed.parallel import single_device_parallel
from repro.models.api import build_model
from repro.train.step import TrainStepConfig, make_train_state, make_train_step

B, S = 2, 32


def _batch(cfg, b=B, s=S):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(1, cfg.vocab_size, size=(b, s + 1), dtype=np.int32)
        )
    }
    if cfg.frontend == "patch_stub":
        batch["patch_emb"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_len, cfg.d_model)), jnp.bfloat16
        )
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_len, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    cfg.validate()
    # spot-check the assignment numbers are encoded exactly
    expect = {
        "granite_20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen3_4b": (36, 2560, 32, 8, 9728, 151936),
        "llama3_405b": (126, 16384, 128, 8, 53248, 128256),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
        "pixtral_12b": (40, 5120, 32, 8, 14336, 131072),
        "whisper_base": (6, 512, 8, 8, 2048, 51865),
    }[arch]
    got = (
        cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
        cfg.d_ff, cfg.vocab_size,
    )
    assert got == expect, f"{arch}: {got} != {expect}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg, single_device_parallel())
    batch = _batch(cfg)
    params, opt = make_train_state(bundle, TrainStepConfig(), jax.random.key(0))
    loss, metrics = jax.jit(bundle.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch} loss is not finite"

    step = jax.jit(make_train_step(bundle, TrainStepConfig()))
    p2, o2, m2 = step(params, opt, batch)
    assert np.isfinite(float(m2["loss"]))
    assert np.isfinite(float(m2["grad_norm"]))
    assert int(o2["step"]) == 1
    # params actually changed (some leaf moved)
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert moved, f"{arch}: no parameter moved after one step"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    bundle = build_model(cfg, single_device_parallel())
    params = bundle.init(jax.random.key(1))
    batch = _batch(cfg, b=1, s=8)
    cache_len = 16
    prompt = {k: (v[:, :8] if k == "tokens" else v) for k, v in batch.items()}
    logits, caches = jax.jit(
        lambda p, b: bundle.prefill(p, b, cache_len=cache_len)
    )(params, prompt)
    assert logits.shape == (1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    # decode position: full-attn archs track absolute positions
    pos = jnp.full((1,), 8, jnp.int32)
    logits2, caches2 = jax.jit(bundle.decode_step)(params, caches, tok, pos)
    assert logits2.shape == (1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_shape_suite_cells():
    names = [c.name for c in SHAPE_SUITE]
    assert names == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    kinds = {c.name: c.kind for c in SHAPE_SUITE}
    assert kinds["decode_32k"] == "decode" and kinds["long_500k"] == "decode"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_long500k_eligibility(arch):
    cfg = get_config(arch)
    from repro.configs.base import shape_cell

    ok, why = cfg.supports_cell(shape_cell("long_500k"))
    if arch in ("xlstm_1_3b", "recurrentgemma_9b"):
        assert ok
    else:
        assert not ok and "sub-quadratic" in why
