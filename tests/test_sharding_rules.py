"""Sharding-rule tests against the production mesh shape (no devices needed:
AbstractMesh carries only the axis-name → size mapping)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.utils.compat import abstract_mesh as AbstractMesh

from repro.configs.base import get_config
from repro.distributed.parallel import ParallelConfig
from repro.distributed import sharding as shd
from repro.models.api import build_model


def _parallel(multi_pod=False):
    if multi_pod:
        mesh = AbstractMesh((2, 16, 16), ("pod", "data", "model"))
        return ParallelConfig(mesh=mesh, dp_axes=("pod", "data"), tp_axis="model")
    mesh = AbstractMesh((16, 16), ("data", "model"))
    return ParallelConfig(mesh=mesh, dp_axes=("data",), tp_axis="model")


def _specs_for(arch, multi_pod=False):
    parallel = _parallel(multi_pod)
    bundle = build_model(get_config(arch), parallel)
    shapes = bundle.param_shapes()
    return shapes, shd.param_pspecs(shapes, parallel), parallel


def _flat(shapes, specs):
    fs, _ = jax.tree_util.tree_flatten_with_path(shapes)
    fp = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    return {jax.tree_util.keystr(p): (l.shape, s) for (p, l), s in zip(fs, fp)}


def test_qwen3_megatron_roles():
    shapes, specs, _ = _specs_for("qwen3_4b")
    table = _flat(shapes, specs)
    emb_shape, emb_spec = table["['embed']"]
    # vocab over tp ONLY — d_model FSDP was measured to poison GSPMD
    # propagation (batch replication); see sharding.py §Perf iter 1.
    assert emb_spec[0] == "model" and emb_spec[1] is None
    for key, (shape, spec) in table.items():
        if key.endswith("['wq']"):
            assert spec[-1] == "model", key  # column-parallel heads
        if key.endswith("['wo']"):
            assert spec[-2] == "model", key  # row-parallel
        if key.endswith("['w_down']"):
            assert spec[-2] == "model", key
        if "norm" in key:
            assert all(s is None for s in spec), key  # replicated


def test_scan_leading_dim_never_sharded():
    shapes, specs, _ = _specs_for("llama3_405b")
    table = _flat(shapes, specs)
    for key, (shape, spec) in table.items():
        if "['layers']" in key and len(shape) >= 2:
            assert spec[0] is None, f"{key}: scan dim sharded {spec}"


def test_every_big_leaf_is_fsdp_sharded_multipod():
    """No >32MiB leaf may be fully replicated on the 512-chip mesh."""
    shapes, specs, parallel = _specs_for("llama3_405b", multi_pod=True)
    table = _flat(shapes, specs)
    for key, (shape, spec) in table.items():
        import numpy as np

        size = int(np.prod(shape)) * 4
        if size > 32 * 2**20:
            assert any(s is not None for s in spec), f"{key} replicated ({size} B)"


def test_moe_expert_weights():
    shapes, specs, _ = _specs_for("grok_1_314b")
    table = _flat(shapes, specs)
    found = 0
    for key, (shape, spec) in table.items():
        if "moe" in key and key.endswith("['w_gate']"):
            found += 1
            assert spec[-1] == "model"  # d_ff TP
            assert spec[0] is None  # scan dim untouched
    assert found


def test_whisper_odd_vocab_falls_back_to_replicated():
    shapes, specs, _ = _specs_for("whisper_base")
    table = _flat(shapes, specs)
    emb_shape, emb_spec = table["['embed']"]
    assert emb_shape[0] == 51865
    assert emb_spec[0] is None  # 51865 % 16 != 0 → vocab dim replicated


@pytest.mark.parametrize(
    "arch,tp,expect_dim",
    [
        ("granite_20b", 16, 3),  # kv=1 < tp → sequence-sharded cache
        ("qwen3_4b", 16, 3),  # kv=8 < 16 → sequence-sharded
        ("qwen3_4b", 4, 2),  # kv=8 % 4 == 0 → head-sharded
    ],
)
def test_cache_specs_head_vs_sequence_sharding(arch, tp, expect_dim):
    mesh = AbstractMesh((256 // tp, tp), ("data", "model"))
    parallel = ParallelConfig(mesh=mesh, dp_axes=("data",), tp_axis="model")
    bundle = build_model(get_config(arch), parallel)
    cache_shapes = jax.eval_shape(lambda: bundle.init_cache(256, 1024))
    cspecs = shd.cache_pspecs(cache_shapes, parallel)
    leaves = jax.tree_util.tree_leaves(cspecs, is_leaf=lambda x: isinstance(x, P))
    shapes = jax.tree_util.tree_leaves(cache_shapes)
    checked = 0
    for spec, sds in zip(leaves, shapes):
        if len(sds.shape) == 5:  # (periods, B, KV, S, hd)
            checked += 1
            assert spec[1] in ("data", ("data",))  # batch on dp
            assert spec[expect_dim] == "model", (arch, tp, spec)
    assert checked


def test_batch_pspec():
    parallel = _parallel(multi_pod=True)
    assert shd.batch_pspec(2, parallel) == P(("pod", "data"), None)


def test_shard_bytes_accounting():
    shapes, specs, parallel = _specs_for("qwen3_4b")
    total = shd.shard_bytes_per_device(
        shapes, specs, dict(parallel.mesh.shape)
    )
    import numpy as np

    full = sum(int(np.prod(l.shape)) * 4 for l in jax.tree.leaves(shapes))
    assert total < full / 32  # 256 chips: far below replication
