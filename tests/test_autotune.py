"""Autotuner — sweep smoke, cache round-trip, resolution order, compile-through.

Everything runs in interpret mode at tiny sizes with a reduced candidate
set: CI asserts the *machinery* (sweeps produce winners, the JSON artifact
round-trips, tuned shapes actually compile and agree with the defaults),
not the timings — wall-clock on shared runners is noise.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, common, ops


@pytest.fixture(autouse=True)
def _clean_cache():
    """Every test starts and ends with an empty in-process winner cache."""
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def test_defaults_table_and_override():
    """Resolution order: override > tuned > DEFAULT_BLOCK_ROWS."""
    assert common.resolve_block_rows("murmur") == 64
    for k in ("bin_histogram", "bucket_probe", "csr_gather", "csr_gather_batched"):
        assert common.resolve_block_rows(k) == 8
    assert common.resolve_block_rows("murmur", 16) == 16  # override wins
    with pytest.raises(KeyError):
        common.resolve_block_rows("no_such_kernel")


def test_sweep_fills_cache_and_resolver_uses_it():
    rec = autotune.sweep_kernel(
        "murmur", n=1024, candidates=(1, 8), repeats=1, interpret=True
    )
    assert rec["block_rows"] in (1, 8)
    assert set(rec["timings_ms"]) == {"1", "8"}
    assert autotune.cached_block_rows("murmur", n=1024) == rec["block_rows"]
    assert common.resolve_block_rows("murmur", n=1024) == rec["block_rows"]
    # override still beats the tuned winner
    assert common.resolve_block_rows("murmur", 32, n=1024) == 32


def test_nearest_bucket_fallback():
    autotune.sweep_kernel("murmur", n=1024, candidates=(8,), repeats=1, interpret=True)
    # far-away size: nearest tuned log2 bucket still informs the call
    assert autotune.cached_block_rows("murmur", n=1 << 22) == 8
    # different kernel/width: no bleed-through
    assert autotune.cached_block_rows("csr_gather", n=1024) is None
    assert autotune.cached_block_rows("murmur", n=None) is None


def test_full_grid_sweep_runs():
    """One cell per kernel (× width for the gathers) sweeps clean."""
    recs = autotune.autotune(
        sizes=(512,), widths=(1, 2), candidates=(8,), repeats=1, interpret=True
    )
    assert len(recs) == 3 + 2 * 2  # 3 single-width kernels + 2 gathers × 2 widths
    assert all(r["block_rows"] == 8 for r in recs)


def test_json_cache_round_trip(tmp_path, monkeypatch):
    """save → clear → load restores winners; REPRO_AUTOTUNE_CACHE names the path."""
    path = tmp_path / "autotune_cache.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))

    rec = autotune.sweep_kernel(
        "csr_gather", n=2048, width=2, candidates=(1, 8), repeats=1, interpret=True
    )
    assert autotune.save_cache() == str(path)
    blob = json.loads(path.read_text())
    assert blob["version"] == 1
    assert blob["entries"][rec["key"]]["block_rows"] == rec["block_rows"]

    autotune.clear_cache()
    assert common.resolve_block_rows("csr_gather", n=2048, width=2) == 8  # default
    assert autotune.load_cache() == 1
    assert (
        common.resolve_block_rows("csr_gather", n=2048, width=2) == rec["block_rows"]
    )
    # missing file is a no-op load, not an error
    autotune.clear_cache()
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "absent.json"))
    assert autotune.load_cache() == 0


def test_tuned_shapes_compile_and_match_defaults():
    """Ops called with block_rows=None under a tuned cache return exactly
    what an explicit block_rows produces — resolution happens outside jit,
    so the tuned integer lands in the same compiled program."""
    rng = np.random.default_rng(7)
    keys = jnp.asarray(rng.integers(0, 1 << 32, 700, dtype=np.uint32))
    starts = jnp.arange(64, dtype=jnp.int32) * 4
    counts = jnp.full((64,), 4, jnp.int32)
    table = jnp.asarray(rng.integers(0, 1 << 31, 256, dtype=np.int32))

    baseline_h = ops.hash_to_buckets(keys, 97, interpret=True)
    baseline_g = ops.csr_gather(starts, counts, table, capacity=256, interpret=True)

    # force a non-default winner for both kernels' buckets
    for kernel, n in [("murmur", 700), ("csr_gather", 256)]:
        autotune.sweep_kernel(kernel, n=n, candidates=(2,), repeats=1, interpret=True)
    assert common.resolve_block_rows("murmur", n=700) == 2

    tuned_h = ops.hash_to_buckets(keys, 97, interpret=True)
    np.testing.assert_array_equal(np.asarray(tuned_h), np.asarray(baseline_h))
    tuned_g = ops.csr_gather(starts, counts, table, capacity=256, interpret=True)
    for a, b in zip(tuned_g, baseline_g):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
