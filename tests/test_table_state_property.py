"""Hypothesis property tests for the versioned mutation API.

Random insert/delete sequences against a multiset oracle, with
compact-equivalence checked at the end of every sequence.  Fixed shapes
(key/batch/query counts) keep the whole run on a handful of jit cache
entries; hypothesis drives the data and the operation order.  Skipped
cleanly when hypothesis is absent (see requirements-dev.txt).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.schema import TableSchema
from repro.core.table import DistributedHashTable
from test_table_state import Oracle, _keys_for, _values_for  # same-dir module

_PN, _PBATCH, _PQ = 256, 16, 64


def check_mutation_sequence(seed, ops, schema, mesh):
    """Apply a random insert/delete sequence; counts match the oracle at every
    step; the compacted final state answers identically to the delta'd one."""
    table = DistributedHashTable(
        mesh, ("d",), hash_range=1 << 10, schema=schema, max_deltas=len(ops) + 1
    )
    rng = np.random.default_rng(seed)
    universe = _keys_for(schema, rng, 64, hi=1 << 10)  # small -> real collisions
    keys = rng.choice(universe, size=_PN)
    vals = _values_for(schema, 0, _PN)
    oracle = Oracle()
    oracle.insert(keys, vals)
    state = table.init(table.schema.pack_keys(keys), values=jnp.asarray(vals))
    queries = rng.choice(universe, size=_PQ)
    q = table.schema.pack_keys(queries)

    for step, op in enumerate(ops):
        batch = rng.choice(universe, size=_PBATCH)
        if op == "insert":
            bvals = _values_for(schema, 1000 * (step + 1), _PBATCH)
            state = state.insert(table.schema.pack_keys(batch), jnp.asarray(bvals))
            oracle.insert(batch, bvals)
        else:
            state = state.delete(table.schema.pack_keys(batch))
            oracle.delete(batch)
        counts = np.asarray(table.query(state, q))
        want = np.array([oracle.count(k) for k in queries], np.int32)
        np.testing.assert_array_equal(counts, want)

    final = np.asarray(table.query(state, q))
    compacted = state.compact()
    assert int(compacted.base.num_dropped) == 0
    np.testing.assert_array_equal(np.asarray(table.query(compacted, q)), final)


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    ops=st.lists(st.sampled_from(["insert", "delete"]), min_size=1, max_size=4),
)
def test_mutation_sequence_property_u32(seed, ops, mesh8):
    check_mutation_sequence(seed, ops, TableSchema("uint32", 1), mesh8)


@settings(max_examples=4, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    ops=st.lists(st.sampled_from(["insert", "delete"]), min_size=1, max_size=3),
)
def test_mutation_sequence_property_u64(seed, ops, mesh8):
    check_mutation_sequence(seed, ops, TableSchema("uint64", 2), mesh8)
