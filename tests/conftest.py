"""Shared test configuration.

Forces an 8-way fake host-device platform *before jax initializes* so
multi-device mesh tests run in-process on CPU-only CI.  Subprocess-based
tests (``tests/multidevice``) set their own ``XLA_FLAGS`` and are
unaffected.  If the user already forced a device count, respect it.
"""
import os

_FORCE = "--xla_force_host_platform_device_count"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=8"
    ).strip()

import jax  # noqa: E402  (import after the flag so it takes effect)

import pytest


@pytest.fixture(scope="session")
def mesh8():
    """An 8-way 1-D mesh over the forced host devices."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 (fake) devices; XLA_FLAGS was overridden")
    return jax.make_mesh((8,), ("d",))


@pytest.fixture(scope="session")
def mesh1():
    """Single-device mesh (degenerate distributed case)."""
    return jax.make_mesh((1,), ("d",))
