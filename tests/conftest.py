"""Shared test configuration.

Forces an 8-way fake host-device platform *before jax initializes* so
multi-device mesh tests run in-process on CPU-only CI.  Subprocess-based
tests (``tests/multidevice``) set their own ``XLA_FLAGS`` and are
unaffected.  If the user already forced a device count, respect it.
"""
import os

_FORCE = "--xla_force_host_platform_device_count"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {_FORCE}=8"
    ).strip()

import jax  # noqa: E402  (import after the flag so it takes effect)

import pytest

# The suite compiles hundreds of XLA programs in one process; on some
# CPU-only hosts the accumulated compiler/runtime state eventually
# crashes the process (segfault in backend_compile ~240 tests in,
# reproducible on the untouched seed).  Dropping every jit dispatch
# cache at module boundaries releases the executables (and their LLVM
# JIT memory) a finished module pinned, which keeps the single-process
# tier-1 run inside what the toolchain tolerates.  No test observes the
# difference: jit cache-size assertions are all intra-test, and the
# next module simply recompiles what it needs.  (A per-run persistent
# compilation cache would also dampen this, but deserialized CPU
# executables abort on the host-callback programs the trainer and
# checkpoint tests compile — jax 0.4.37 — so it stays off.)


@pytest.fixture(autouse=True, scope="module")
def _bounded_compiler_state():
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def mesh8():
    """An 8-way 1-D mesh over the forced host devices."""
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 (fake) devices; XLA_FLAGS was overridden")
    return jax.make_mesh((8,), ("d",))


@pytest.fixture(scope="session")
def mesh1():
    """Single-device mesh (degenerate distributed case)."""
    return jax.make_mesh((1,), ("d",))
