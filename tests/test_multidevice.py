"""Multi-device semantics tests.

These run in subprocesses with ``--xla_force_host_platform_device_count=8``
so the main pytest process keeps the default single CPU device (the
assignment requires fake devices only where needed).
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SCRIPTS = Path(__file__).resolve().parent / "multidevice"


def run_script(name: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, str(SCRIPTS / name)],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=str(REPO),
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{name} failed (rc={proc.returncode})\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
        )
    return proc.stdout


@pytest.mark.slow
def test_multidevice_hashtable():
    out = run_script("run_hashtable_checks.py")
    assert "ALL_OK" in out


@pytest.mark.slow
def test_multidevice_training():
    out = run_script("run_train_checks.py")
    assert "ALL_OK" in out


@pytest.mark.slow
def test_multidevice_parallel_semantics():
    out = run_script("run_parallel_semantics.py")
    assert "ALL_OK" in out
