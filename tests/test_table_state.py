"""Mutation semantics of the versioned plan/execute table API.

Oracle-driven tests of the LSM-style ``TableState``: insert→query,
delete→query, delete-then-reinsert, compact-equivalence (a compacted table
answers identically to the delta'd table), delta-ring overflow, and the
acceptance contract that a ``build → insert → delete → plan`` program
composes under a single outer ``jax.jit`` with no device→host sync after
planning.  Runs the full schema grid (uint32 and packed-uint64 keys, 1 and
2 value columns) on both the 1-device and the 8-way forced-host mesh.
"""
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.schema import TableSchema
from repro.core.state import TableState
from repro.core.table import (
    DistributedHashTable,
    join_to_pairs,
    retrieval_to_lists,
)

SCHEMAS = [
    pytest.param(TableSchema("uint32", 1), id="u32x1"),
    pytest.param(TableSchema("uint64", 2), id="u64x2"),
]


def _keys_for(schema, rng, n, lo=0, hi=1 << 16):
    """Random keys in the schema's host dtype (u64 keys exercise both lanes)."""
    if schema.key_dtype == "uint64":
        base = rng.integers(lo, hi, size=n).astype(np.uint64)
        return (base << np.uint64(32)) | rng.integers(0, 1 << 30, size=n).astype(np.uint64)
    return rng.integers(lo, hi, size=n, dtype=np.uint32)


def _values_for(schema, start, n):
    ids = np.arange(start, start + n, dtype=np.int32)
    if schema.value_cols == 1:
        return ids
    return np.stack([ids] + [ids * 7 + c for c in range(1, schema.value_cols)], axis=1)


def _value_rows(values):
    """Per-row hashable view of a value array: int or tuple per row."""
    if values.ndim == 1:
        return [int(v) for v in values]
    return [tuple(int(x) for x in row) for row in values]


class Oracle:
    """Reference multiset table with epoch-aware deletes."""

    def __init__(self):
        self.rows = {}  # key -> list of value rows

    def insert(self, keys, values):
        for k, v in zip(keys.tolist(), _value_rows(values)):
            self.rows.setdefault(int(k), []).append(v)

    def delete(self, keys):
        for k in keys.tolist():
            self.rows.pop(int(k), None)

    def count(self, k):
        return len(self.rows.get(int(k), []))

    def values(self, k):
        return sorted(self.rows.get(int(k), []), key=repr)


def _assert_state_matches(table, state, queries, oracle):
    q = table.schema.pack_keys(queries)
    counts = np.asarray(table.query(state, q))
    want = np.array([oracle.count(k) for k in queries], np.int32)
    np.testing.assert_array_equal(counts, want)
    res = table.retrieve(state, q)
    assert int(res.num_dropped) == 0
    per_q = retrieval_to_lists(res)
    for i, k in enumerate(queries):
        got = sorted(_value_rows(np.asarray(per_q[i])), key=repr)
        assert got == oracle.values(k), f"query {i} (key {int(k)})"
    return res


@pytest.mark.parametrize("schema", SCHEMAS)
@pytest.mark.parametrize("meshname", ["mesh1", "mesh8"])
def test_mutation_lifecycle_matches_oracle(schema, meshname, request):
    """insert→query, delete→query, delete-then-reinsert, compact-equivalence."""
    mesh = request.getfixturevalue(meshname)
    d = 8 if meshname == "mesh8" else 1
    table = DistributedHashTable(mesh, ("d",), hash_range=1 << 12, schema=schema)
    rng = np.random.default_rng(42 + d + schema.value_cols)

    n = 512
    keys = _keys_for(schema, rng, n)
    vals = _values_for(schema, 0, n)
    oracle = Oracle()
    oracle.insert(keys, vals)
    state = table.init(jnp.asarray(keys) if schema.key_dtype == "uint32" else keys,
                       values=jnp.asarray(vals))
    assert int(state.num_dropped) == 0

    queries = np.concatenate([keys[: 128 - 2 * d], _keys_for(schema, rng, 2 * d, hi=1 << 14)])

    # -- insert ------------------------------------------------------------
    ins = _keys_for(schema, rng, 8 * d, lo=1 << 16, hi=1 << 17)
    ins_vals = _values_for(schema, 10_000, len(ins))
    state = state.insert(ins, jnp.asarray(ins_vals))
    oracle.insert(ins, ins_vals)
    queries = np.concatenate([queries[: -8 * d], ins])
    _assert_state_matches(table, state, queries, oracle)

    # -- delete (hits base rows and delta rows) ----------------------------
    dels = np.concatenate([keys[:16], ins[: 2 * d]])
    state = state.delete(dels)
    oracle.delete(dels)
    _assert_state_matches(table, state, queries, oracle)

    # -- delete-then-reinsert: later inserts are visible again -------------
    re_keys = np.concatenate([keys[:8], keys[8:16]])  # previously deleted
    re_vals = _values_for(schema, 20_000, len(re_keys))
    state = state.insert(re_keys, jnp.asarray(re_vals))
    oracle.insert(re_keys, re_vals)
    res_delta = _assert_state_matches(table, state, queries, oracle)

    # -- compact-equivalence ----------------------------------------------
    compacted = state.compact()
    assert int(compacted.num_dropped) == 0
    assert compacted.epoch == 0 and len(compacted.deltas) == 0
    res_comp = _assert_state_matches(table, compacted, queries, oracle)
    np.testing.assert_array_equal(
        np.asarray(res_comp.counts), np.asarray(res_delta.counts)
    )
    # join path agrees across the delta'd and compacted states
    q = table.schema.pack_keys(queries)
    ja = sorted(map(tuple, join_to_pairs(table.inner_join(state, q)).tolist()))
    jb = sorted(map(tuple, join_to_pairs(table.inner_join(compacted, q)).tolist()))
    assert ja == jb
    assert int(table.join_size(state, q)) == len(ja)


def test_delta_ring_overflow_raises(mesh8):
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 10, max_deltas=2
    )
    rng = np.random.default_rng(7)
    state = table.init(jnp.asarray(rng.integers(0, 1 << 14, 256, dtype=np.uint32)))
    for _ in range(2):
        state = state.insert(
            jnp.asarray(rng.integers(0, 1 << 14, 8, dtype=np.uint32))
        )
    with pytest.raises(RuntimeError, match="delta ring full"):
        state.insert(jnp.asarray(rng.integers(0, 1 << 14, 8, dtype=np.uint32)))
    # compacting frees the ring
    state = state.compact()
    state = state.insert(jnp.asarray(rng.integers(0, 1 << 14, 8, dtype=np.uint32)))
    assert state.epoch == 1


def test_tombstone_overflow_reported(mesh8):
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 10, tombstone_capacity=8
    )
    rng = np.random.default_rng(11)
    keys = rng.integers(0, 1 << 14, 256, dtype=np.uint32)
    state = table.init(jnp.asarray(keys))
    state = state.delete(jnp.asarray(keys[:24]))  # 24 deletes into 8 slots
    assert int(state.tombstones.num_dropped) == 16
    assert int(state.num_dropped) == 16


@pytest.mark.parametrize("schema", SCHEMAS)
def test_composed_program_single_outer_jit(mesh8, schema):
    """build → insert → delete → plan_retrieve under ONE outer jax.jit.

    The plan is built with explicit capacities (zero device work), so the
    jitted program contains every table phase and must trace with no
    device→host sync anywhere — a concretization attempt inside the trace
    would raise.  Executes on the 8-way mesh at every schema width.
    """
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12, schema=schema)
    rng = np.random.default_rng(3 + schema.key_lanes)
    keys = _keys_for(schema, rng, 512)
    vals = _values_for(schema, 0, 512)
    ins = _keys_for(schema, rng, 64, lo=1 << 16, hi=1 << 17)
    ins_vals = _values_for(schema, 5000, 64)
    dels = keys[:32]
    queries = np.concatenate([keys[:96], ins[:32]])

    plan = table.plan_retrieve(
        num_queries=len(queries), out_capacity=1024, seg_capacity=1024
    )
    qplan = table.plan_query(num_queries=len(queries))

    @jax.jit
    def program(k, v, ik, iv, dk, q):
        st = table.init(k, v)
        st = st.insert(ik, iv)
        st = st.delete(dk)
        return qplan(st, q), plan(st, q)

    counts, res = program(
        table.schema.pack_keys(keys),
        jnp.asarray(vals),
        table.schema.pack_keys(ins),
        jnp.asarray(ins_vals),
        table.schema.pack_keys(dels),
        table.schema.pack_keys(queries),
    )
    assert int(res.num_dropped) == 0

    oracle = Oracle()
    oracle.insert(keys, vals)
    oracle.insert(ins, ins_vals)
    oracle.delete(dels)
    want = np.array([oracle.count(k) for k in queries], np.int32)
    np.testing.assert_array_equal(np.asarray(counts), want)
    per_q = retrieval_to_lists(res)
    for i, k in enumerate(queries):
        assert sorted(_value_rows(np.asarray(per_q[i])), key=repr) == oracle.values(k)


def test_plan_survives_state_evolution(mesh8):
    """One plan executes against states of different delta depth and after
    compaction (jit re-keys on state structure, capacities stay fixed)."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 11)
    rng = np.random.default_rng(23)
    keys = rng.integers(0, 1 << 15, 512, dtype=np.uint32)
    queries = jnp.asarray(keys[:128])
    s0 = table.init(jnp.asarray(keys))
    plan = table.plan_retrieve(s0, queries)  # counts-round sizing
    r0 = plan(s0, queries)
    assert int(r0.num_dropped) == 0
    ins = rng.integers(1 << 15, 1 << 16, 16, dtype=np.uint32)  # disjoint range
    s1 = s0.insert(jnp.asarray(ins))
    s2 = s1.delete(jnp.asarray(ins[:8]))  # touches nothing in the query set
    r2 = plan(s2, queries)
    assert int(r2.num_dropped) == 0
    np.testing.assert_array_equal(np.asarray(r0.counts), np.asarray(r2.counts))
    r3 = plan(s2.compact(), queries)
    np.testing.assert_array_equal(np.asarray(r0.counts), np.asarray(r3.counts))


def test_plan_out_capacity_exact(mesh8):
    """Count-first planning sizes the output CSR exactly (ROADMAP item)."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 11)
    rng = np.random.default_rng(31)
    base = rng.choice(np.arange(1 << 15, dtype=np.uint32), size=128, replace=False)
    keys = np.repeat(base, rng.integers(1, 9, size=128))
    keys = np.concatenate([keys, base[: (-len(keys)) % 8]])
    state = table.init(jnp.asarray(keys))
    queries = np.concatenate([base[:120], np.full(8, base[0], np.uint32)])
    seg, out = table.plan_caps(state, jnp.asarray(queries))
    # exact: max per-device total result count over the 8 query shards
    cnt = Counter(keys.tolist())
    n_local = len(queries) // 8
    per_dev = [
        sum(cnt[int(k)] for k in queries[s * n_local : (s + 1) * n_local])
        for s in range(8)
    ]
    assert out == max(per_dev)
    res = table.retrieve(state, jnp.asarray(queries))  # planned caps
    assert int(res.num_dropped) == 0
    # the output buffer is the lane-rounded exact size, not a 2x guess
    assert res.values.shape[0] // 8 == max(8, -(-out // 8) * 8)
    assert seg >= max(per_dev) // 8  # sanity: seg covers the widest block


def test_compact_sizing_stays_flat_at_steady_live_size(mesh8):
    """Live-count compaction sizing: repeated insert/delete/compact cycles at
    a steady live row count must NOT grow the base arrays (ROADMAP open
    item — the worst-case sizing grew them ≈(1 + slack)× per cycle)."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12)
    rng = np.random.default_rng(41)
    keys = rng.choice(
        np.arange(1 << 14, dtype=np.uint32), size=1024, replace=False
    )
    state = table.init(jnp.asarray(keys))
    live = list(keys)
    sizes = []
    for cycle in range(3):
        fresh = rng.choice(
            np.setdiff1d(
                np.arange(1 << 14, dtype=np.uint32), np.array(live, np.uint32)
            ),
            size=256,
            replace=False,
        )
        state = state.insert(jnp.asarray(fresh))
        dead = np.array(live[:256], np.uint32)
        state = state.delete(jnp.asarray(dead))
        live = live[256:] + list(fresh)  # steady live size: 1024
        state = state.compact()
        assert int(state.num_dropped) == 0
        sizes.append(int(state.base.local.values.shape[0]))
        # spot-check correctness after each fold
        q = np.concatenate([np.array(live[:32], np.uint32), dead[:8]])
        want = np.array([1] * 32 + [0] * 8, np.int32)
        np.testing.assert_array_equal(
            np.asarray(table.query(state, jnp.asarray(q))), want
        )
    assert sizes[0] == sizes[1] == sizes[2], sizes


def test_should_compact_and_auto_compact(mesh8):
    """should_compact fires on ring-full / tombstone-load / overflow, and
    insert(auto_compact=True) folds instead of raising on a full ring."""
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 10, max_deltas=2, tombstone_capacity=16
    )
    rng = np.random.default_rng(43)
    state = table.init(jnp.asarray(rng.integers(0, 1 << 14, 256, dtype=np.uint32)))
    assert not state.should_compact()
    # tombstone load threshold
    state = state.delete(jnp.asarray(rng.integers(0, 1 << 14, 8, dtype=np.uint32)))
    assert state.should_compact(tombstone_load=0.5)
    assert not state.should_compact(tombstone_load=0.9)
    # ring-full trigger + auto_compact avoids the RuntimeError
    for _ in range(2):
        state = state.insert(
            jnp.asarray(rng.integers(0, 1 << 14, 8, dtype=np.uint32))
        )
    assert state.should_compact(tombstone_load=1.1)  # ring full alone fires
    state = state.insert(
        jnp.asarray(rng.integers(0, 1 << 14, 8, dtype=np.uint32)),
        auto_compact=True,
    )
    assert state.epoch == 1  # compacted, then inserted the new delta


def test_legacy_state_lift_equivalence(mesh8):
    """Shims accept a bare DistributedHashGraph and a TableState equally."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 11)
    rng = np.random.default_rng(37)
    keys = rng.integers(0, 1 << 15, 512, dtype=np.uint32)
    queries = jnp.asarray(keys[:64])
    dhg = table.build(jnp.asarray(keys))  # legacy: bare graph
    st = table.init(jnp.asarray(keys))  # new: versioned state
    assert isinstance(st, TableState)
    np.testing.assert_array_equal(
        np.asarray(table.query(dhg, queries)), np.asarray(table.query(st, queries))
    )
    a = table.retrieve(dhg, queries, out_capacity=512, seg_capacity=512)
    b = table.retrieve(st, queries, out_capacity=512, seg_capacity=512)
    np.testing.assert_array_equal(np.asarray(a.values), np.asarray(b.values))
    np.testing.assert_array_equal(np.asarray(a.offsets), np.asarray(b.offsets))
    # deleting on a lifted legacy state grows the tombstone buffer lazily
    st2 = table.delete(dhg, queries[:8])
    assert int(st2.tombstones.count) == 8
    assert (np.asarray(table.query(st2, queries))[:8] == 0).all()
