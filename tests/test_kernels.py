"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes/dtypes per the assignment contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# murmur
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 100, 128, 1024, 5000])
@pytest.mark.parametrize("table_size", [7, 128, 1 << 20])
def test_murmur_kernel_matches_ref(n, table_size):
    rng = np.random.default_rng(n)
    keys = jnp.asarray(rng.integers(0, 2**32 - 1, size=n, dtype=np.uint32))
    got = ops.hash_to_buckets(keys, table_size, interpret=True)
    want = ref.hash_to_buckets_ref(keys, table_size, seed=0x9747B28C)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", [0, 1, 0xDEADBEEF])
def test_murmur_kernel_seeds(seed):
    keys = jnp.arange(777, dtype=jnp.uint32)
    got = ops.hash_to_buckets(keys, 1 << 16, seed, interpret=True)
    want = ref.hash_to_buckets_ref(keys, 1 << 16, seed=seed)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# histogram
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,num_bins", [(100, 16), (1024, 256), (4096, 1000), (513, 300)])
def test_histogram_kernel_matches_ref(n, num_bins):
    rng = np.random.default_rng(n + num_bins)
    bins = jnp.asarray(rng.integers(0, num_bins, size=n, dtype=np.int32))
    got = ops.bin_histogram(bins, num_bins, interpret=True)
    want = ref.histogram_ref(bins, num_bins)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(np.asarray(got).sum()) == n


def test_histogram_skewed():
    # all keys in one bin — the duplicate-heavy stress the paper cares about
    bins = jnp.full((2048,), 3, jnp.int32)
    got = ops.bin_histogram(bins, 256, interpret=True)
    assert int(got[3]) == 2048
    assert int(got.sum()) == 2048


# ---------------------------------------------------------------------------
# bucket probe
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,v,dup", [(512, 256, 1), (1024, 128, 4), (300, 64, 16)])
def test_bucket_probe_matches_ref(n, v, dup):
    from repro.core import hashgraph

    rng = np.random.default_rng(v)
    base = rng.integers(0, 1 << 24, size=max(1, n // dup), dtype=np.uint32)
    keys = jnp.asarray(np.repeat(base, dup)[:n])
    hg = hashgraph.build(keys, table_size=v)
    queries = jnp.asarray(
        np.concatenate([base[:32], rng.integers(0, 1 << 24, size=32, dtype=np.uint32)])
    )
    b = hg.bucket_of(queries)
    starts, ends = hg.offsets[b], hg.offsets[b + 1]
    max_probe = 4 * dup + 8
    got = ops.bucket_probe(
        hg.keys, starts, ends, queries, max_probe=max_probe, interpret=True
    )
    want = ref.bucket_probe_ref(starts, ends, queries, hg.keys, max_probe)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# CSR gather (retrieval pass 2)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "n_rows,max_run,cap_slack", [(100, 4, 64), (1000, 16, 8), (257, 1, 0), (64, 64, -100)]
)
def test_csr_gather_kernel_matches_ref(n_rows, max_run, cap_slack):
    rng = np.random.default_rng(n_rows * 7 + max_run)
    table = jnp.asarray(rng.integers(0, 1 << 20, size=4096, dtype=np.int32))
    counts = jnp.asarray(rng.integers(0, max_run + 1, size=n_rows, dtype=np.int32))
    starts = jnp.asarray(rng.integers(0, 4096 - max_run, size=n_rows, dtype=np.int32))
    total = int(np.asarray(counts).sum())
    capacity = max(8, total + cap_slack)  # covers exact, slack, and overflow
    off, rows, vals, dropped = ops.csr_gather(
        starts, counts, table, capacity=capacity, interpret=True
    )
    want_vals, want_rows = ref.csr_gather_ref(starts, counts, table, capacity)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(want_vals))
    np.testing.assert_array_equal(np.asarray(rows), np.asarray(want_rows))
    assert int(dropped) == max(0, total - capacity)
    # brute-force oracle: concatenation of the runs
    flat = np.concatenate(
        [np.asarray(table)[s : s + c] for s, c in zip(np.asarray(starts), np.asarray(counts))]
        + [np.zeros(0, np.int32)]
    )
    m = min(total, capacity)
    np.testing.assert_array_equal(np.asarray(vals)[:m], flat[:m])


def test_csr_gather_kernel_uint32_roundtrip():
    """uint32 tables (values >= 2**31) survive the int32 kernel lanes."""
    from repro.core import hashgraph as hgm

    table = jnp.asarray(np.array([1, 2**31 + 5, 2**32 - 2, 7], np.uint32))
    counts = jnp.asarray(np.array([2, 2], np.int32))
    starts = jnp.asarray(np.array([1, 0], np.int32))
    _, _, got, _ = ops.csr_gather(starts, counts, table, capacity=8, interpret=True)
    _, _, want, _ = hgm.csr_gather(starts, counts, table, 8, fill=jnp.uint32(0))
    assert got.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(got)[:4], np.asarray(want)[:4])


def test_csr_gather_kernel_matches_core():
    """Kernel path == repro.core.hashgraph.csr_gather (the production oracle)."""
    from repro.core import hashgraph as hgm

    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.integers(0, 1 << 20, size=512, dtype=np.int32))
    counts = jnp.asarray(rng.integers(0, 5, size=200, dtype=np.int32))
    starts = jnp.asarray(rng.integers(0, 500, size=200, dtype=np.int32))
    for cap in (8, 256, 1024):
        got = ops.csr_gather(starts, counts, table, capacity=cap, interpret=True)
        want = hgm.csr_gather(starts, counts, table, cap)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize(
    "s_dim,n_rows,cap", [(1, 64, 128), (8, 100, 64), (5, 37, 8), (16, 256, 520)]
)
def test_csr_gather_batched_matches_per_source(s_dim, n_rows, cap):
    """Fused (sources, tiles) grid == S independent csr_gather calls,
    including offsets/rows/values and the summed overflow count."""
    from repro.core import hashgraph as hgm

    rng = np.random.default_rng(s_dim * 31 + n_rows)
    table = jnp.asarray(rng.integers(0, 1 << 20, size=777, dtype=np.int32))
    counts = rng.integers(0, 6, size=(s_dim, n_rows)).astype(np.int32)
    starts = rng.integers(0, 770, size=(s_dim, n_rows)).astype(np.int32)
    off, rows, vals, dropped = ops.csr_gather_batched(
        jnp.asarray(starts), jnp.asarray(counts), table, capacity=cap, interpret=True
    )
    want_dropped = 0
    for s in range(s_dim):
        w_off, w_rows, w_vals, w_drop = hgm.csr_gather(
            jnp.asarray(starts[s]), jnp.asarray(counts[s]), table, cap
        )
        np.testing.assert_array_equal(np.asarray(off[s]), np.asarray(w_off))
        np.testing.assert_array_equal(np.asarray(rows[s]), np.asarray(w_rows))
        np.testing.assert_array_equal(np.asarray(vals[s]), np.asarray(w_vals))
        want_dropped += int(w_drop)
    assert int(dropped) == want_dropped


def test_csr_gather_batched_multicol_and_uint32():
    """Multi-column tables reuse the kernel's row resolution; uint32 values
    survive the int32 lanes (bitcast round trip)."""
    from repro.core import hashgraph as hgm

    rng = np.random.default_rng(12)
    s_dim, n_rows, cap = 4, 50, 64
    counts = rng.integers(0, 4, size=(s_dim, n_rows)).astype(np.int32)
    starts = rng.integers(0, 250, size=(s_dim, n_rows)).astype(np.int32)
    table3 = jnp.asarray(rng.integers(0, 1 << 20, size=(256, 3), dtype=np.int32))
    _, _, vals, _ = ops.csr_gather_batched(
        jnp.asarray(starts), jnp.asarray(counts), table3, capacity=cap, interpret=True
    )
    for s in range(s_dim):
        _, _, w_vals, _ = hgm.csr_gather(
            jnp.asarray(starts[s]), jnp.asarray(counts[s]), table3, cap
        )
        np.testing.assert_array_equal(np.asarray(vals[s]), np.asarray(w_vals))
    tableu = jnp.asarray(
        rng.integers(0, 2**32, size=256, dtype=np.uint64).astype(np.uint32)
    )
    _, _, valsu, _ = ops.csr_gather_batched(
        jnp.asarray(starts), jnp.asarray(counts), tableu, capacity=cap, interpret=True
    )
    assert valsu.dtype == jnp.uint32
    for s in range(s_dim):
        _, _, w_vals, _ = hgm.csr_gather(
            jnp.asarray(starts[s]),
            jnp.asarray(counts[s]),
            tableu,
            cap,
            fill=jnp.uint32(0xFFFFFFFF),
        )
        np.testing.assert_array_equal(np.asarray(valsu[s]), np.asarray(w_vals))


@pytest.mark.parametrize("nlayers,cols", [(1, 1), (3, 1), (4, 2)])
def test_csr_gather_layers_matches_ref(nlayers, cols):
    """The layered owner-side fusion (one grid packing every layer's runs
    slot-major/layer-minor) matches the jnp reference used off-TPU —
    including multi-layer table offsetting and multi-column payloads."""
    from repro.core import multi_hashgraph as mhg

    rng = np.random.default_rng(nlayers * 7 + cols)
    s_dim, n_rows, cap = 4, 40, 96
    sizes = [int(rng.integers(50, 200)) for _ in range(nlayers)]
    shape = lambda t: (t,) if cols == 1 else (t, cols)  # noqa: E731
    tables = tuple(
        jnp.asarray(rng.integers(0, 1 << 20, size=shape(t), dtype=np.int32))
        for t in sizes
    )
    starts = np.zeros((nlayers, s_dim, n_rows), np.int32)
    counts = np.zeros((nlayers, s_dim, n_rows), np.int32)
    off = 0
    for l, t in enumerate(sizes):
        counts[l] = rng.integers(0, 4, size=(s_dim, n_rows))
        starts[l] = rng.integers(0, t - 4, size=(s_dim, n_rows)) + off
        off += t
    vals, dropped = ops.csr_gather_layers(
        jnp.asarray(starts), jnp.asarray(counts), tables, capacity=cap, interpret=True
    )
    w_vals, w_dropped = mhg._csr_gather_layers_ref(
        jnp.asarray(starts), jnp.asarray(counts), tables, cap
    )
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(w_vals))
    assert int(dropped) == int(w_dropped)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # (b, hq, hkv, sq, skv, d, causal, window)
    (1, 2, 2, 128, 128, 64, True, None),
    (2, 4, 2, 128, 128, 64, True, None),  # GQA 2:1
    (1, 4, 1, 256, 256, 32, True, None),  # GQA 4:1 (MQA)
    (1, 2, 2, 128, 128, 64, False, None),  # encoder (full)
    (1, 2, 2, 256, 256, 32, True, 64),  # sliding window
    (1, 2, 1, 1, 384, 64, True, None),  # decode: 1 query vs long cache
    (1, 2, 2, 100, 100, 64, True, None),  # ragged seq (pad inside kernel)
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    b, hq, hkv, sq, skv, d, causal, window = case
    rng = np.random.default_rng(hash(case) % 2**31)
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, skv, d)), dtype)
    got = ops.flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_kv=64, interpret=True
    )
    group = hq // hkv
    want = jnp.stack(
        [
            ref.attention_ref(
                q[i], k[i], v[i], causal=causal, window=window, q_heads_per_kv=group
            )
            for i in range(b)
        ]
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_matches_ref_long_decode():
    # decode against 4k cache — exercises many kv blocks + accumulator carry
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 2, 1, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 4096, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 4096, 64)), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q[0], k[0], v[0], causal=True)[None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# sLSTM recurrence (VMEM-pinned recurrent weights)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,h,s,hd,t_block",
    [(1, 1, 8, 16, 8), (2, 2, 32, 32, 16), (1, 4, 100, 64, 32), (2, 1, 256, 128, 256)],
)
def test_slstm_kernel_matches_ref(b, h, s, hd, t_block):
    rng = np.random.default_rng(b * 1000 + s)
    pre = jnp.asarray(rng.standard_normal((b, h, s, 4, hd)) * 0.5, jnp.float32)
    r = jnp.asarray(rng.standard_normal((h, 4, hd, hd)) / np.sqrt(hd), jnp.float32)
    z = jnp.zeros((b, h, hd), jnp.float32)
    m0 = jnp.full((b, h, hd), -1e30, jnp.float32)
    got_hs, got_fin = ops.slstm_recurrence(
        pre, r, z, z, z, m0, t_block=t_block, interpret=True
    )
    want_hs, want_fin = ref.slstm_sequence_ref(pre, r, z, z, z, m0)
    np.testing.assert_allclose(
        np.asarray(got_hs), np.asarray(want_hs), rtol=2e-5, atol=2e-5
    )
    for g, w in zip(got_fin, want_fin):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=2e-5, atol=2e-5)


def test_slstm_kernel_matches_model_block():
    """Kernel path == repro.models.ssm.slstm_block (the production oracle)."""
    import dataclasses

    from repro.configs.base import get_smoke_config
    from repro.models import ssm

    cfg = dataclasses.replace(get_smoke_config("xlstm_1_3b"), dtype="float32")
    params = ssm.init_slstm(jax.random.key(0), cfg)
    rng = np.random.default_rng(3)
    b, s, d = 2, 24, cfg.d_model
    x = jnp.asarray(rng.standard_normal((b, s, d)) * 0.1, jnp.float32)
    want, _ = ssm.slstm_block(params, x, cfg)

    # reproduce the block wiring around the kernel
    h_heads = cfg.num_heads
    hd = d // h_heads
    xin = jnp.asarray(
        np.asarray(
            __import__("repro.models.layers", fromlist=["rmsnorm"]).rmsnorm(
                x, params["norm"]
            )
        )
    )
    pre = (jnp.dot(xin, params["w_in"]) + params["b"]).astype(jnp.float32)
    pre = pre.reshape(b, s, 4, h_heads, hd).transpose(0, 3, 1, 2, 4)
    z = jnp.zeros((b, h_heads, hd), jnp.float32)
    m0 = jnp.full((b, h_heads, hd), -1e30, jnp.float32)
    hs, _ = ops.slstm_recurrence(pre, params["r"], z, z, z, m0, t_block=8, interpret=True)
    hs = hs.transpose(0, 2, 1, 3).reshape(b, s, d)
    from repro.models import layers as L

    hs = L.rmsnorm(hs, params["out_norm"])
    got = x + jnp.dot(hs, params["w_down"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)
