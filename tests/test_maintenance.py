"""Incremental compaction — fold_oldest oracle grid, policy, stats, skew guard.

The fold contract: ``fold_oldest(state, k)`` followed by the remaining
deltas must answer every query exactly like the un-folded state AND like a
full ``compact()`` — across delete-then-reinsert histories whose tombstone
epochs straddle the fold boundary (the epoch-remap edge cases), at both
schema widths, on mesh1 and mesh8.  On a coherent stack the fold must also
be *layer-local*: zero collective rounds in the jitted executor (the
property that keeps background folds off the serving collective budget).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hashing, maintenance
from repro.core.maintenance import CompactionPolicy, TableStats, fold_oldest
from repro.core.schema import TableSchema
from repro.core.table import DistributedHashTable, retrieval_to_lists
from test_fused_routing import count_primitive
from test_table_state import Oracle, _keys_for, _value_rows, _values_for

SCHEMAS = [
    pytest.param(TableSchema("uint32", 1), id="u32x1"),
    pytest.param(TableSchema("uint64", 2), id="u64x2"),
]


def _deep_state(table, schema, rng, d):
    """base + 4 deltas with tombstones at epochs straddling any fold point.

    Deletes land at epochs 1, 3 and 4 (a delete's epoch is the delta count
    when it is issued), so a fold of k=2 must discard the epoch-1
    tombstone (spent inside the folded prefix) and keep/remap the later
    ones; reinserts after deletes keep the visibility rule honest.
    """
    n = 256
    keys = _keys_for(schema, rng, n)
    vals = _values_for(schema, 0, n)
    oracle = Oracle()
    oracle.insert(keys, vals)
    state = table.init(table.schema.pack_keys(keys), values=jnp.asarray(vals))

    batches = []
    for i in range(4):
        ins = _keys_for(schema, rng, 8 * d, lo=(1 << 16) + i * 4096, hi=(1 << 16) + (i + 1) * 4096)
        ins_vals = _values_for(schema, 10_000 + 1000 * i, len(ins))
        batches.append((ins, ins_vals))

    # epoch-1 tombstones: delete base rows after the first insert
    ins, ins_vals = batches[0]
    state = state.insert(table.schema.pack_keys(ins), jnp.asarray(ins_vals))
    oracle.insert(ins, ins_vals)
    dels1 = keys[:16]
    state = state.delete(table.schema.pack_keys(dels1))
    oracle.delete(dels1)

    ins, ins_vals = batches[1]
    state = state.insert(table.schema.pack_keys(ins), jnp.asarray(ins_vals))
    oracle.insert(ins, ins_vals)

    ins, ins_vals = batches[2]
    state = state.insert(table.schema.pack_keys(ins), jnp.asarray(ins_vals))
    oracle.insert(ins, ins_vals)
    # epoch-3 tombstones: hit base rows AND delta-1 rows
    dels3 = np.concatenate([keys[16:24], batches[0][0][: 2 * d]])
    state = state.delete(table.schema.pack_keys(dels3))
    oracle.delete(dels3)

    # reinsert some epoch-1-deleted keys in the LAST delta: visible again,
    # and the fold must keep them visible whichever side of the boundary
    # the tombstone lands on.
    re_keys = keys[:8]
    re_vals = _values_for(schema, 20_000, len(re_keys))
    state = state.insert(table.schema.pack_keys(re_keys), jnp.asarray(re_vals))
    oracle.insert(re_keys, re_vals)
    # epoch-4 tombstones on delta-2 rows
    dels4 = batches[2][0][: 2 * d]
    state = state.delete(table.schema.pack_keys(dels4))
    oracle.delete(dels4)

    queries = np.concatenate(
        [keys[:48], batches[0][0][: 2 * d], batches[2][0][: 4 * d], _keys_for(schema, rng, 2 * d)]
    )
    return state, oracle, queries


def _check(table, state, queries, oracle):
    q = table.schema.pack_keys(queries)
    counts = np.asarray(table.query(state, q))
    want = np.array([oracle.count(k) for k in queries], np.int32)
    np.testing.assert_array_equal(counts, want)
    res = table.retrieve(state, q, out_capacity=4096, seg_capacity=4096)
    assert int(res.num_dropped) == 0
    per_q = retrieval_to_lists(res)
    for i, k in enumerate(queries):
        got = sorted(_value_rows(np.asarray(per_q[i])), key=repr)
        assert got == oracle.values(k), f"query {i}"


@pytest.mark.parametrize("schema", SCHEMAS)
@pytest.mark.parametrize("meshname", ["mesh1", "mesh8"])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_fold_oldest_matches_oracle_and_full_compact(schema, meshname, k, request):
    """fold_oldest(state, k) ∘ remaining deltas ≡ unfolded ≡ full compact()."""
    mesh = request.getfixturevalue(meshname)
    d = 8 if meshname == "mesh8" else 1
    table = DistributedHashTable(mesh, ("d",), hash_range=1 << 12, schema=schema)
    rng = np.random.default_rng(3 + d + schema.value_cols + k)
    state, oracle, queries = _deep_state(table, schema, rng, d)
    assert len(state.deltas) == 4

    folded = fold_oldest(state, k)
    assert len(folded.deltas) == 4 - k
    assert folded.coherent
    _check(table, folded, queries, oracle)

    # agreement with the full rebuild, and folds compose
    compacted = state.compact()
    _check(table, compacted, queries, oracle)
    refolded = fold_oldest(folded, 4 - k)  # fold the rest
    assert len(refolded.deltas) == 0
    _check(table, refolded, queries, oracle)


def test_fold_oldest_tombstone_remap(mesh8):
    """Tombstones spent inside the folded prefix are discarded; later ones
    shift down by k and keep hiding exactly the surviving deltas."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 11)
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 1 << 14, 256, dtype=np.uint32)
    state = table.init(jnp.asarray(keys))
    state = state.insert(jnp.asarray(rng.integers(1 << 14, 1 << 15, 8, dtype=np.uint32)))
    state = state.delete(jnp.asarray(keys[:4]))  # epoch 1: inside fold of k=2
    state = state.insert(jnp.asarray(rng.integers(1 << 14, 1 << 15, 8, dtype=np.uint32)))
    state = state.insert(jnp.asarray(rng.integers(1 << 14, 1 << 15, 8, dtype=np.uint32)))
    state = state.delete(jnp.asarray(keys[4:8]))  # epoch 3: survives fold of k=2
    assert int(state.tombstones.count) == 8

    folded = fold_oldest(state, 2)
    # epoch-1 entries discarded, epoch-3 entries remapped to 3-2=1
    assert int(folded.tombstones.count) == 4
    surviving = np.asarray(folded.tombstones.epochs)
    assert sorted(surviving[surviving >= 0].tolist()) == [1, 1, 1, 1]
    # the remap preserves semantics
    c = np.asarray(table.query(folded, jnp.asarray(keys[:8])))
    np.testing.assert_array_equal(c, np.zeros(8, np.int32))


def test_fold_zero_and_clamp(mesh8):
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 11)
    rng = np.random.default_rng(11)
    state = table.init(jnp.asarray(rng.integers(0, 1 << 14, 256, dtype=np.uint32)))
    assert fold_oldest(state, 0) is state
    assert fold_oldest(state, 3) is state  # no deltas: clamps to identity
    state = state.insert(jnp.asarray(rng.integers(0, 1 << 14, 8, dtype=np.uint32)))
    folded = fold_oldest(state, 99)  # clamps to the delta depth
    assert len(folded.deltas) == 0


def test_fold_is_collective_free_on_coherent_stack(mesh8):
    """The serving guarantee: the jitted fold contains ZERO all_to_all
    primitives (a full compact pays a pre-balance + build exchange)."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12)
    rng = np.random.default_rng(13)
    keys = rng.integers(0, 1 << 14, 512, dtype=np.uint32)
    state = table.init(jnp.asarray(keys))
    for _ in range(3):
        state = state.insert(jnp.asarray(rng.integers(0, 1 << 14, 64, dtype=np.uint32)))
    state = state.delete(jnp.asarray(keys[:16]))

    jx = jax.make_jaxpr(lambda s: maintenance.exec_fold(table, s, k=2))(state)
    assert count_primitive(jx.jaxpr, "all_to_all") == 0
    # ... while the full compact does exchange (sanity: the comparison the
    # fold-vs-full bench is measuring is real)
    jc = jax.make_jaxpr(
        lambda s: table._compact_jit(s, capacity=1024, rebuild_rows=None)
    )(state)
    assert count_primitive(jc.jaxpr, "all_to_all") > 0


def test_fold_incoherent_falls_back_to_full_compact(mesh8):
    """Mixed-split stacks cannot fold locally: fold_oldest = compact()."""
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 11, coherent_deltas=False
    )
    rng = np.random.default_rng(17)
    keys = rng.integers(0, 1 << 14, 256, dtype=np.uint32)
    state = table.init(jnp.asarray(keys))
    state = state.insert(jnp.asarray(rng.integers(0, 1 << 14, 16, dtype=np.uint32)))
    state = state.insert(jnp.asarray(rng.integers(0, 1 << 14, 16, dtype=np.uint32)))
    assert not state.coherent
    before = np.asarray(table.query(state, jnp.asarray(keys[:64])))
    folded = fold_oldest(state, 1)
    assert len(folded.deltas) == 0  # full fold
    np.testing.assert_array_equal(
        before, np.asarray(table.query(folded, jnp.asarray(keys[:64])))
    )


# ---------------------------------------------------------------------------
# CompactionPolicy + TableStats + should_compact shim
# ---------------------------------------------------------------------------


def _stats(**kw):
    base = dict(
        delta_depth=0,
        base_rows=1024,
        delta_rows=0,
        tombstone_count=0,
        tombstone_capacity=64,
        tombstone_dropped=0,
        num_dropped=0,
    )
    base.update(kw)
    return TableStats(**base)


def test_policy_triggers():
    p = CompactionPolicy(max_delta_depth=4, tombstone_load=0.5, max_dropped=10)
    assert not p.due(_stats())
    assert p.due(_stats(delta_depth=4))
    assert not p.due(_stats(delta_depth=3))
    assert p.due(_stats(tombstone_count=32))  # load 0.5
    assert not p.due(_stats(tombstone_count=31))
    assert p.due(_stats(tombstone_dropped=1))
    assert p.due(_stats(num_dropped=11))
    assert not p.due(_stats(num_dropped=10))
    # disabled triggers
    off = CompactionPolicy(max_delta_depth=None, tombstone_load=2.0, max_dropped=None, tombstone_overflow=False)
    assert not off.due(_stats(delta_depth=100, tombstone_dropped=5, num_dropped=999))


def test_policy_fold_amount_escalates():
    p = CompactionPolicy(max_delta_depth=8, fold_k=2)
    assert p.fold_amount(_stats(delta_depth=0)) == 0
    assert p.fold_amount(_stats(delta_depth=8)) == 2  # incremental
    assert p.fold_amount(_stats(delta_depth=1)) == 1  # clamped
    # tombstone pressure folds everything (frees the buffer)
    assert p.fold_amount(_stats(delta_depth=8, tombstone_dropped=1)) == 8
    assert p.fold_amount(_stats(delta_depth=8, tombstone_count=40)) == 8
    # escalation is orthogonal to depth: a saturated delete buffer needs
    # the full compact even when there are no deltas to fold
    assert not p.escalates(_stats(delta_depth=8))
    assert p.escalates(_stats(delta_depth=0, tombstone_count=40))
    assert p.escalates(_stats(delta_depth=0, tombstone_dropped=1))
    # dropped-rows pressure escalates too: incremental folds carry the drop
    # tally into the new base, only compact() rebuilds without it
    pd = CompactionPolicy(max_dropped=10)
    assert pd.escalates(_stats(delta_depth=0, num_dropped=11))
    assert pd.fold_amount(_stats(delta_depth=4, num_dropped=11)) == 4


def test_state_stats_and_should_compact_shim(mesh8):
    table = DistributedHashTable(
        mesh8, ("d",), hash_range=1 << 10, max_deltas=2, tombstone_capacity=16
    )
    rng = np.random.default_rng(19)
    state = table.init(jnp.asarray(rng.integers(0, 1 << 14, 256, dtype=np.uint32)))
    st = state.stats()
    assert st.delta_depth == 0 and st.base_rows > 0
    assert st.tombstone_capacity == 0 and st.tombstone_load == 0.0
    assert not state.should_compact()

    state = state.delete(jnp.asarray(rng.integers(0, 1 << 14, 8, dtype=np.uint32)))
    st = state.stats()
    assert st.tombstone_count == 8 and st.tombstone_capacity == 16
    assert state.should_compact(tombstone_load=0.5)
    assert not state.should_compact(tombstone_load=0.9)

    for _ in range(2):
        state = state.insert(jnp.asarray(rng.integers(0, 1 << 14, 8, dtype=np.uint32)))
    assert state.stats().delta_depth == 2
    assert state.should_compact(tombstone_load=1.1)  # ring full alone
    assert not state.should_compact(tombstone_load=1.1, ring_full=False)


# ---------------------------------------------------------------------------
# Delta-dispatch skew guard
# ---------------------------------------------------------------------------


def _narrow_batch(table, state, n):
    """Distinct keys whose base-space hash all lands in ONE owner's range."""
    splits = np.asarray(state.base.hash_splits)
    cand = np.arange(1 << 16, 1 << 18, dtype=np.uint32)
    h = np.asarray(
        hashing.hash_to_buckets(jnp.asarray(cand), table.hash_range, seed=table.seed)
    )
    narrow = cand[h < splits[1]][:n]
    assert len(narrow) == n
    return narrow


def test_skew_guard_falls_back_instead_of_dropping(mesh8):
    """A hash-range-skewed insert would overflow the frozen-splits dispatch;
    the guard routes it to an incoherent delta with zero dropped rows."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12)
    rng = np.random.default_rng(23)
    keys = rng.integers(0, 1 << 14, 512, dtype=np.uint32)
    state = table.init(jnp.asarray(keys))
    narrow = _narrow_batch(table, state, 512)

    assert table.skew_fallbacks == 0
    s2 = table.insert(state, jnp.asarray(narrow))
    assert table.skew_fallbacks == 1
    assert not s2.coherent  # legacy-routed delta
    assert int(s2.num_dropped) == 0  # the point: no rows lost
    c = np.asarray(table.query(s2, jnp.asarray(narrow[:64])))
    assert (c >= 1).all()

    # a well-spread insert does NOT trip the guard
    s3 = table.insert(state, jnp.asarray(rng.integers(0, 1 << 14, 512, dtype=np.uint32)))
    assert table.skew_fallbacks == 1
    assert s3.coherent


def test_skew_guard_off_reproduces_drops(mesh8):
    """Without the guard the same batch drops rows (the ROADMAP failure)."""
    table = DistributedHashTable(mesh8, ("d",), hash_range=1 << 12, skew_guard=False)
    rng = np.random.default_rng(23)
    keys = rng.integers(0, 1 << 14, 512, dtype=np.uint32)
    state = table.init(jnp.asarray(keys))
    narrow = _narrow_batch(table, state, 512)
    s2 = table.insert(state, jnp.asarray(narrow))
    assert s2.coherent and int(s2.num_dropped) > 0
    assert table.skew_fallbacks == 0
