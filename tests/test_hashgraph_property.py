"""Hypothesis property tests on the hash table's system invariants."""
from collections import Counter

import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import hashgraph, hashing

keys_strategy = st.lists(
    st.integers(min_value=0, max_value=2**32 - 2), min_size=1, max_size=300
)


def _counts_oracle(build, queries):
    c = Counter(build)
    return np.array([c[int(q)] for q in queries], dtype=np.int32)


@settings(max_examples=40, deadline=None)
@given(build=keys_strategy, queries=keys_strategy, c_inv=st.integers(1, 4))
def test_multiplicity_exact_any_multiset(build, queries, c_inv):
    """query_count == multiset multiplicity for ANY input, any load factor."""
    table_size = max(1, len(build) // c_inv)  # C in {1..4} equivalents
    hg = hashgraph.build(jnp.asarray(np.array(build, np.uint32)), table_size)
    got = hashgraph.query_count_sorted(hg, jnp.asarray(np.array(queries, np.uint32)))
    np.testing.assert_array_equal(np.asarray(got), _counts_oracle(build, queries))


@settings(max_examples=30, deadline=None)
@given(build=keys_strategy)
def test_offsets_monotone_and_partition(build):
    """offsets is a monotone CSR partition of exactly the input keys."""
    n = len(build)
    hg = hashgraph.build(jnp.asarray(np.array(build, np.uint32)), max(1, n))
    off = np.asarray(hg.offsets)
    assert (np.diff(off) >= 0).all()
    assert off[0] == 0 and off[-1] == n
    # every key is stored exactly once, bucket contents hash to the bucket
    assert sorted(np.asarray(hg.keys).tolist()) == sorted(
        np.array(build, np.uint32).tolist()
    )
    buckets = np.asarray(hg.bucket_of(hg.keys))
    for v in range(int(hg.table_size)):
        seg = buckets[off[v]: off[v + 1]]
        assert (seg == v).all()


@settings(max_examples=30, deadline=None)
@given(build=keys_strategy, queries=keys_strategy)
def test_probe_and_sorted_queries_agree(build, queries):
    """Paper-faithful linear probe == beyond-paper binary-search query."""
    n = len(build)
    hg = hashgraph.build(jnp.asarray(np.array(build, np.uint32)), max(1, n))
    q = jnp.asarray(np.array(queries, np.uint32))
    sorted_counts = hashgraph.query_count_sorted(hg, q)
    probe_counts = hashgraph.query_count_probe(hg, q, max_probe=n + 1)
    np.testing.assert_array_equal(np.asarray(sorted_counts), np.asarray(probe_counts))


@settings(max_examples=30, deadline=None)
@given(build=keys_strategy, queries=keys_strategy)
def test_join_size_symmetric(build, queries):
    """|A ⋈ B| = Σ_k cnt_A(k)·cnt_B(k) is symmetric in A and B."""
    a = np.array(build, np.uint32)
    b = np.array(queries, np.uint32)
    hga = hashgraph.build(jnp.asarray(a), max(1, len(a)))
    hgb = hashgraph.build(jnp.asarray(b), max(1, len(b)))
    ab = int(np.asarray(hashgraph.query_count_sorted(hga, jnp.asarray(b))).sum())
    ba = int(np.asarray(hashgraph.query_count_sorted(hgb, jnp.asarray(a))).sum())
    assert ab == ba


@settings(max_examples=30, deadline=None)
@given(build=keys_strategy)
def test_contains_iff_member(build):
    a = np.array(build, np.uint32)
    hg = hashgraph.build(jnp.asarray(a), max(1, len(a)))
    members = jnp.asarray(a)
    assert bool(np.asarray(hashgraph.contains(hg, members)).all())
    # a key absent from the input is never reported present
    absent = np.setdiff1d(
        np.arange(50, dtype=np.uint32), a.astype(np.uint32)
    )
    if len(absent):
        got = np.asarray(hashgraph.contains(hg, jnp.asarray(absent)))
        assert not got.any()


@settings(max_examples=20, deadline=None)
@given(
    build=keys_strategy,
    seed1=st.integers(0, 2**31 - 1),
    seed2=st.integers(0, 2**31 - 1),
)
def test_seed_changes_layout_not_semantics(build, seed1, seed2):
    a = np.array(build, np.uint32)
    hg1 = hashgraph.build(jnp.asarray(a), max(1, len(a)), seed=seed1)
    hg2 = hashgraph.build(jnp.asarray(a), max(1, len(a)), seed=seed2)
    q = jnp.asarray(a)
    np.testing.assert_array_equal(
        np.asarray(hashgraph.query_count_sorted(hg1, q)),
        np.asarray(hashgraph.query_count_sorted(hg2, q)),
    )


@settings(max_examples=20, deadline=None)
@given(words=st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=16))
def test_stream_hash_deterministic_and_order_sensitive(words):
    w = jnp.asarray(np.array([words], np.uint32))
    h1 = int(hashing.murmur3_stream(w)[0])
    h2 = int(hashing.murmur3_stream(w)[0])
    assert h1 == h2
    if len(words) > 1 and words[0] != words[-1]:
        rev = jnp.asarray(np.array([words[::-1]], np.uint32))
        assert int(hashing.murmur3_stream(rev)[0]) != h1
